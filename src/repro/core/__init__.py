from repro.core.request import (
    SLO_BATCH1,
    SLO_BATCH2,
    SLO_CLASSES,
    SLO_INTERACTIVE,
    Request,
    make_request,
)

__all__ = ["Request", "make_request", "SLO_CLASSES", "SLO_INTERACTIVE",
           "SLO_BATCH1", "SLO_BATCH2"]
