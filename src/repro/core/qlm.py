"""QLM controller: global queue + group formation + violation-triggered
global scheduling (paper §3 lifecycle).

Works against either the real engine cluster (``repro.serving`` +
``core.lso.QLMAgent``) or the discrete-event simulator (``repro.sim``);
both expose instances as ``core.global_scheduler.InstanceInfo``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence

from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.request import Request
from repro.core.request_group import (RequestGroup, classify_into_groups,
                                      create_request_groups)
from repro.core.rwt_estimator import RWTEstimator


@dataclasses.dataclass
class QLMConfig:
    avg_batch_size: float = 32.0
    delta: float = 4.0            # request-group size multiple (§8.3: δ=4)
    z_conservative: float = 1.0   # RWT tail factor
    reschedule_on_arrival: bool = True
    # min sim-seconds between solver invocations: the paper runs the global
    # scheduler OFF the critical path ("overheads can be hidden", §8.3), so
    # back-to-back arrivals share one reordering.
    reschedule_cooldown: float = 2.0
    # Run repro.analysis.invariants.check_queue_layer at every tick()
    # (group placement/ownership, SLO-min, model homogeneity).  Also
    # forced on by QLINT_INVARIANTS=1.  Debug aid.
    debug_invariants: bool = False


class QLMController:
    def __init__(self, instances: Sequence[InstanceInfo],
                 cfg: Optional[QLMConfig] = None, seed: int = 0):
        self.cfg = cfg or QLMConfig()
        self.instances = list(instances)
        self.estimator = RWTEstimator(self.cfg.z_conservative)
        self.scheduler = GlobalScheduler(self.estimator, seed=seed)
        # the global queue: single-replica request store (RabbitMQ stand-in,
        # §4 Fault Tolerance) — virtual queues only hold group pointers.
        self.global_queue: List[Request] = []
        self.groups: List[RequestGroup] = []
        self.finished: List[Request] = []
        # requests 429'd before entering the global queue (admission control
        # / backpressure): never scheduled, but they COUNT as SLO misses —
        # attainment over admitted requests only would reward rejecting
        # everything hard to serve
        self.rejected: List[Request] = []
        self._last_reschedule = -math.inf

    @property
    def max_group(self) -> int:
        return max(1, int(self.cfg.avg_batch_size * self.cfg.delta))

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> None:
        """API-gateway entry: enqueue, classify into a group, reschedule if
        the RWT estimator predicts a violation.

        Raises ``ValueError`` when NO instance can serve ``req.model`` —
        once, here, instead of letting ``predict_violation`` report an
        unfixable violation every cooldown tick (solver thrash).
        """
        if not any(req.model in i.hw_by_model for i in self.instances):
            raise ValueError(f"no instance can serve model {req.model}")
        self.global_queue.append(req)
        g = classify_into_groups(req, self.groups, max_group=self.max_group)
        if g is None:
            g = RequestGroup(model=req.model, slo=req.slo)
            g.add(req)
            self.groups.append(g)
            self._place_new_group(g, now)
        elif not self._placed(g):
            # liveness: the group existed but is reachable from no instance
            # (an infeasible-solve set_order/_edf_fallback dropped it, or a
            # VQ popped it while momentarily done) — without re-placement
            # the new request would strand in the global queue until an
            # unrelated violation triggers a full reschedule
            self._place_new_group(g, now)
        if self.cfg.reschedule_on_arrival and \
                now - self._last_reschedule >= self.cfg.reschedule_cooldown and \
                self.scheduler.predict_violation(self.instances, now):
            self.reschedule(now)

    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        """Bulk arrival: form groups with Algorithm 1 k-means, then solve."""
        self.global_queue.extend(requests)
        new_groups = create_request_groups(
            requests, avg_batch_size=self.cfg.avg_batch_size,
            delta=self.cfg.delta)
        self.groups.extend(new_groups)
        self.reschedule(now)

    def _placed(self, g: RequestGroup) -> bool:
        """Is ``g`` reachable from at least one instance's virtual queue?"""
        return any(g is q for inst in self.instances
                   for q in inst.virtual_queue.groups)

    def record_rejection(self, req: Request, now: float) -> None:
        """Admission-control / backpressure rejection (§9 option (c)):
        the request never enters the global queue, but attainment
        accounting must still see it as a miss."""
        req.rejected = True
        if req.completion_time is None:
            req.completion_time = now
        self.rejected.append(req)

    def _place_new_group(self, g: RequestGroup, now: float) -> None:
        """Cheap placement for a singleton group (full solve happens on
        violation): minimize the RWT-estimated drain of (queue + group) —
        heterogeneity-aware (Design Principle #3: an A10 absorbs
        proportionally less work than an A100), unlike a raw request count.
        """
        candidates = [i for i in self.instances if g.model in i.hw_by_model]
        if not candidates:
            raise ValueError(f"no instance can serve model {g.model}")
        wl = g.workload_profile()

        def drain(i):
            theta = i.hw(g.model).throughput(wl)
            backlog = i.virtual_queue.pending_requests() + len(g.pending())
            swap = 0.0 if i.current_model in (None, g.model) \
                else i.hw(g.model).swap_time
            return backlog * wl.mu_output / theta + swap

        inst = min(candidates, key=drain)
        inst.virtual_queue.groups.append(g)

    # ------------------------------------------------------------------
    def reschedule(self, now: float):
        self.gc_groups()
        self._last_reschedule = now
        return self.scheduler.schedule(self.groups, self.instances, now)

    def tick(self, now: float) -> bool:
        """Periodic violation check (returns True if it rescheduled).

        Respects ``reschedule_cooldown`` like the submit path: under
        sustained overload ``predict_violation`` stays true on every tick,
        and re-solving each time churns the VQ orders (each re-solve moves
        group heads, firing the agents' head-change eviction LSO) without
        any new information to act on.
        """
        if now - self._last_reschedule < self.cfg.reschedule_cooldown:
            self._check_invariants()
            return False
        rescheduled = False
        if self.scheduler.predict_violation(self.instances, now):
            self.reschedule(now)
            rescheduled = True
        self._check_invariants()
        return rescheduled

    _inv_sampler = None

    def _check_invariants(self) -> None:
        """Tick-boundary hook: queue-layer state (group placement, member
        ownership) is only quiescent between scheduler actions."""
        if not self.cfg.debug_invariants:
            from repro.analysis.invariants import invariants_enabled
            if not invariants_enabled():
                return
        if self._inv_sampler is None:
            from repro.analysis.invariants import InvariantSampler
            self._inv_sampler = InvariantSampler()
        if self._inv_sampler.due():
            from repro.analysis.invariants import check_queue_layer
            check_queue_layer(self, where="controller.tick")

    def gc_groups(self) -> None:
        self.groups = [g for g in self.groups if not g.done()]
        still = []
        for r in self.global_queue:
            (self.finished if r.finished() else still).append(r)
        self.global_queue = still

    # ------------------------------------------------------------------
    def all_requests(self) -> List[Request]:
        return self.finished + self.global_queue

    def slo_attainment(self, now: Optional[float] = None) -> float:
        """Fraction of SCORED requests that met their TTFT SLO.

        Scored = served requests (TTFT recorded) + definite misses that
        never got a first token: admission rejections, shed/expired
        requests, and — when ``now`` is given — requests still queued past
        their deadline (stranded).  Counting only TTFT-recorded requests
        silently inflates attainment exactly when the system is dropping
        or stranding traffic.  Client cancellations without a first token
        are excluded (the client walked away; the system didn't fail it)
        unless the deadline had already passed.
        """
        scored = hits = 0
        for r in self.all_requests() + self.rejected:
            met = r.slo_met()
            if met is not None:
                scored += 1
                hits += int(met)
                continue
            # no first token ever recorded
            if r.rejected or r.expired or r.shed:
                scored += 1          # dropped without service: miss
            elif now is not None and now > r.deadline:
                scored += 1          # past deadline and still unstarted: miss
        if scored == 0:
            return 1.0
        return hits / scored
