"""QLM controller: global queue + group formation + violation-triggered
global scheduling (paper §3 lifecycle).

Works against either the real engine cluster (``repro.serving`` +
``core.lso.QLMAgent``) or the discrete-event simulator (``repro.sim``);
both expose instances as ``core.global_scheduler.InstanceInfo``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence

from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.request import Request
from repro.core.request_group import (RequestGroup, classify_into_groups,
                                      create_request_groups)
from repro.core.rwt_estimator import RWTEstimator


@dataclasses.dataclass
class QLMConfig:
    avg_batch_size: float = 32.0
    delta: float = 4.0            # request-group size multiple (§8.3: δ=4)
    z_conservative: float = 1.0   # RWT tail factor
    reschedule_on_arrival: bool = True
    # min sim-seconds between solver invocations: the paper runs the global
    # scheduler OFF the critical path ("overheads can be hidden", §8.3), so
    # back-to-back arrivals share one reordering.
    reschedule_cooldown: float = 2.0
    # Run repro.analysis.invariants.check_queue_layer at every tick()
    # (group placement/ownership, SLO-min, model homogeneity).  Also
    # forced on by QLINT_INVARIANTS=1.  Debug aid.
    debug_invariants: bool = False
    # -- fault tolerance (§4: the global queue survives engine death) -----
    # Redelivery attempts per request after its serving engine dies; the
    # (budget+1)-th death quarantines the request as FAILED — the poison
    # policy: a request that kills retry_budget+1 engines stops being
    # retried instead of crash-looping the cluster.
    retry_budget: int = 2
    # Exponential backoff for redelivered requests:
    # min(cap, base * 2**(n-1)) seconds after the nth redelivery.
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    # Missed-heartbeat supervision: None disables (sparse-tick callers,
    # e.g. unit tests driving tick() manually, must not read as silence).
    # An instance is DEGRADED after missing `degraded_after_missed`
    # windows and DEAD after `dead_after_missed`.
    heartbeat_timeout_s: Optional[float] = None
    degraded_after_missed: int = 1
    dead_after_missed: int = 3
    # Consecutive transient (non-fatal) engine errors before the
    # supervisor gives up on the instance; any successful heartbeat
    # resets the strike counter.
    transient_strikes: int = 3


# Instance health states (supervision state machine — see
# docs/fault_tolerance.md).  DEAD is terminal: a crashed engine's pool
# and resident state are gone; recovery means standing up a NEW instance.
HEALTHY = "healthy"
DEGRADED = "degraded"
DEAD = "dead"


@dataclasses.dataclass
class InstanceHealth:
    state: str = HEALTHY
    last_heartbeat: Optional[float] = None
    strikes: int = 0              # consecutive transient errors
    missed: int = 0               # consecutive missed heartbeat windows
    died_at: Optional[float] = None
    cause: Optional[str] = None


class QLMController:
    def __init__(self, instances: Sequence[InstanceInfo],
                 cfg: Optional[QLMConfig] = None, seed: int = 0):
        self.cfg = cfg or QLMConfig()
        self.instances = list(instances)
        self.estimator = RWTEstimator(self.cfg.z_conservative)
        self.scheduler = GlobalScheduler(self.estimator, seed=seed)
        # the global queue: single-replica request store (RabbitMQ stand-in,
        # §4 Fault Tolerance) — virtual queues only hold group pointers.
        self.global_queue: List[Request] = []
        self.groups: List[RequestGroup] = []
        self.finished: List[Request] = []
        # requests 429'd before entering the global queue (admission control
        # / backpressure): never scheduled, but they COUNT as SLO misses —
        # attainment over admitted requests only would reward rejecting
        # everything hard to serve
        self.rejected: List[Request] = []
        # requests quarantined after exhausting their redelivery budget or
        # losing every instance that could serve their model (poison
        # policy).  Observability list only: the requests themselves stay
        # in global_queue/finished (stamped terminal), so attainment
        # iterates them exactly once via all_requests().
        self.failed: List[Request] = []
        # supervision: per-instance health, index-aligned with
        # self.instances (the simulator rebuilds InstanceInfo views but
        # keeps the order)
        self.health: List[InstanceHealth] = [InstanceHealth()
                                             for _ in self.instances]
        self.redeliveries = 0        # total redelivery events (stats)
        # optional engine handles, index-aligned with instances: lets
        # mark_dead() reclaim a dead engine's resident requests and lets
        # the terminal-state invariant cross-check engine residency
        self._engines: Optional[List] = None
        self._last_reschedule = -math.inf

    # -- supervision -------------------------------------------------------
    def attach_engines(self, engines: Sequence) -> None:
        """Register the engine behind each instance (order-aligned with
        ``instances``).  Optional: without it, mark_dead() can only sweep
        queue-visible state (``_served_by`` / snapshots)."""
        assert len(engines) == len(self.instances), \
            (len(engines), len(self.instances))
        self._engines = list(engines)

    def is_alive(self, idx: int) -> bool:
        return self.health[idx].state != DEAD

    def alive_instances(self) -> List[InstanceInfo]:
        return [inst for i, inst in enumerate(self.instances)
                if self.is_alive(i)]

    def alive_fraction(self) -> float:
        if not self.instances:
            return 0.0
        return len(self.alive_instances()) / len(self.instances)

    def can_serve(self, model: str) -> bool:
        """Does any ALIVE instance serve ``model``?"""
        return any(model in i.hw_by_model for i in self.alive_instances())

    def heartbeat(self, idx: int, now: float) -> None:
        """A successful agent iteration: reset the strike/missed counters
        and recover a DEGRADED instance (DEAD stays dead — the pool is
        gone; recovery means attaching a new instance)."""
        h = self.health[idx]
        if h.state == DEAD:
            return
        h.last_heartbeat = now
        h.strikes = 0
        h.missed = 0
        if h.state == DEGRADED:
            h.state = HEALTHY

    def check_heartbeats(self, now: float) -> None:
        """Tick-side liveness: an instance whose agent has not heartbeated
        for ``heartbeat_timeout_s`` misses windows; enough misses degrade
        then kill it (a wedged engine strands its whole virtual queue)."""
        timeout = self.cfg.heartbeat_timeout_s
        if timeout is None:
            return
        for idx, h in enumerate(self.health):
            if h.state == DEAD:
                continue
            if h.last_heartbeat is None:
                h.last_heartbeat = now   # start the window at first sight
                continue
            h.missed = int((now - h.last_heartbeat) // timeout)
            if h.missed >= self.cfg.dead_after_missed:
                self.mark_dead(idx, now, cause=(
                    f"missed {h.missed} heartbeat window(s) of {timeout}s"))
            elif h.missed >= self.cfg.degraded_after_missed \
                    and h.state == HEALTHY:
                h.state = DEGRADED

    def report_engine_failure(self, idx: int, exc: BaseException, now: float,
                              engine=None) -> str:
        """Agent-exception supervision: fatal failures (``EngineCrashed`` /
        ``EngineDead`` — ``exc.fatal``) kill the instance immediately;
        transient errors strike it (DEGRADED) until
        ``cfg.transient_strikes`` consecutive strikes give up on it.
        Returns the resulting health state."""
        h = self.health[idx]
        if h.state == DEAD:
            return DEAD
        if engine is not None and self._engines is not None:
            self._engines[idx] = engine
        if getattr(exc, "fatal", False):
            self.mark_dead(idx, now, cause=repr(exc), engine=engine)
            return DEAD
        h.strikes += 1
        if h.strikes >= self.cfg.transient_strikes:
            self.mark_dead(idx, now, cause=(
                f"{h.strikes} consecutive transient errors "
                f"(last: {exc!r})"), engine=engine)
            return DEAD
        h.state = DEGRADED
        return DEGRADED

    def backoff(self, n: int) -> float:
        """Redelivery backoff after the nth delivery failure (n >= 1):
        exponential, capped."""
        return min(self.cfg.backoff_cap_s,
                   self.cfg.backoff_base_s * (2.0 ** (n - 1)))

    def mark_dead(self, idx: int, now: float, cause: str = "killed",
                  engine=None) -> None:
        """Quarantine instance ``idx`` and recover its work (§4 fault
        tolerance: requests live in the global queue, virtual queues hold
        pointers — so losing an engine loses no request):

          1. the dead VQ is emptied (groups are pointers; the requests
             are still in the global queue);
          2. the engine's resident requests (slots + pushback limbo) are
             abandoned — KV accounting freed host-side, nothing stamped
             terminal — and redelivered with backoff;
          3. snapshots pinned in the dead pool are discarded (pins
             released so the dead BlockManager's accounting stays
             conserved) and their requests restart cleanly;
          4. requests whose model no longer has an alive instance are
             quarantined as recorded misses;
          5. surviving groups are re-placed on alive instances and the
             scheduler re-solves without the dead one.
        """
        h = self.health[idx]
        if h.state == DEAD:
            return
        h.state = DEAD
        h.died_at = now
        h.cause = cause
        if engine is None and self._engines is not None:
            engine = self._engines[idx]
        dead_inst = self.instances[idx]
        dead_inst.virtual_queue.groups.clear()
        dead_pool = getattr(engine, "block_mgr", None)
        # 2. reclaim engine-resident requests (crash salvage)
        if engine is not None and hasattr(engine, "abandon"):
            for r in engine.abandon():
                if not r.finished():
                    self._redeliver(r, now)
        # 3./4. sweep the global queue: dead-pool snapshots, stragglers
        # still tagged as served by the dead instance, unservable models
        for r in list(self.global_queue):
            if r.finished():
                continue
            snap = r.snapshot
            if snap is not None and isinstance(snap, dict) \
                    and snap.get("pin_owner") is not None \
                    and snap.get("pin_owner") is dead_pool:
                # pinned in the dead pool: the pinned pages died with the
                # engine — release the pins (conserves the dead pool's
                # accounting) and restart from the prompt
                if snap.get("pinned"):
                    snap["pin_owner"].release_pins(snap["pinned"],
                                                   snap.get("pin_epoch"))
                r.restart()
            if getattr(r, "_served_by", None) == idx \
                    and getattr(r, "_in_flight", False):
                self._redeliver(r, now)
            if not r.finished() and not self.can_serve(r.model):
                self._quarantine(r, now, f"model {r.model} unservable "
                                         f"after instance {idx} died")
        # 5. re-place orphaned groups, then re-solve over the survivors
        self.gc_groups()
        for g in self.groups:
            if not g.done() and not self._placed(g):
                self._place_new_group(g, now)
        if self.alive_instances():
            self.reschedule(now)
        self._check_invariants()

    def _redeliver(self, r: Request, now: float) -> None:
        """Return an in-flight request to the (still-placed) global queue
        with retry budget + exponential backoff."""
        r._in_flight = False
        r._served_by = None
        r.redeliveries += 1
        if r.redeliveries > self.cfg.retry_budget:
            self._quarantine(r, now, f"retry budget exhausted after "
                                     f"{r.redeliveries} deliveries")
            return
        self.redeliveries += 1
        r.not_before = now + self.backoff(r.redeliveries)
        if r.snapshot is None and (r.generated > 0 or r._prefill_done > 0):
            # generation state died with the engine and no snapshot
            # survived: restart cleanly (first_token_time kept — never
            # double-counted in attainment; see Request.restart)
            r.restart()

    def _quarantine(self, r: Request, now: float, cause: str) -> None:
        """Poison/unservable terminal state: a recorded SLO miss.  The
        request is stamped finished so group cursors skip it and gc moves
        it to ``finished``; ``failed`` makes attainment score it a miss
        even if a pre-crash first token landed in time."""
        r.failed = True
        r.fail_cause = cause
        r._in_flight = False
        r._served_by = None
        snap = r.snapshot
        if snap is not None and isinstance(snap, dict) and snap.get("pinned") \
                and snap.get("pin_owner") is not None:
            snap["pin_owner"].release_pins(snap["pinned"],
                                           snap.get("pin_epoch"))
        r.snapshot = None
        if r.completion_time is None:
            r.completion_time = now
        self.failed.append(r)

    @property
    def max_group(self) -> int:
        return max(1, int(self.cfg.avg_batch_size * self.cfg.delta))

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """API-gateway entry: enqueue, classify into a group, reschedule if
        the RWT estimator predicts a violation.

        When NO alive instance can serve ``req.model`` the request is
        recorded as a 400-style rejection (an attainment miss) and
        ``False`` is returned — once, here, instead of raising out of the
        serve path (one bad request must not kill the loop) or letting
        ``predict_violation`` report an unfixable violation every
        cooldown tick (solver thrash)."""
        if not self.can_serve(req.model):
            self.record_rejection(req, now)
            return False
        self.global_queue.append(req)
        g = classify_into_groups(req, self.groups, max_group=self.max_group)
        if g is None:
            g = RequestGroup(model=req.model, slo=req.slo)
            g.add(req)
            self.groups.append(g)
            self._place_new_group(g, now)
        elif not self._placed(g):
            # liveness: the group existed but is reachable from no instance
            # (an infeasible-solve set_order/_edf_fallback dropped it, or a
            # VQ popped it while momentarily done) — without re-placement
            # the new request would strand in the global queue until an
            # unrelated violation triggers a full reschedule
            self._place_new_group(g, now)
        if self.cfg.reschedule_on_arrival and \
                now - self._last_reschedule >= self.cfg.reschedule_cooldown and \
                self.scheduler.predict_violation(self.alive_instances(), now):
            self.reschedule(now)
        return True

    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        """Bulk arrival: form groups with Algorithm 1 k-means, then solve."""
        self.global_queue.extend(requests)
        new_groups = create_request_groups(
            requests, avg_batch_size=self.cfg.avg_batch_size,
            delta=self.cfg.delta)
        self.groups.extend(new_groups)
        self.reschedule(now)

    def _placed(self, g: RequestGroup) -> bool:
        """Is ``g`` reachable from at least one instance's virtual queue?"""
        return any(g is q for inst in self.instances
                   for q in inst.virtual_queue.groups)

    def record_rejection(self, req: Request, now: float) -> None:
        """Admission-control / backpressure rejection (§9 option (c)):
        the request never enters the global queue, but attainment
        accounting must still see it as a miss."""
        req.rejected = True
        if req.completion_time is None:
            req.completion_time = now
        self.rejected.append(req)

    def _place_new_group(self, g: RequestGroup, now: float) -> None:
        """Cheap placement for a singleton group (full solve happens on
        violation): minimize the RWT-estimated drain of (queue + group) —
        heterogeneity-aware (Design Principle #3: an A10 absorbs
        proportionally less work than an A100), unlike a raw request count.
        """
        candidates = [i for i in self.alive_instances()
                      if g.model in i.hw_by_model]
        if not candidates:
            # submit() rejects unservable models and mark_dead()
            # quarantines orphans before re-placing, so this is a
            # controller bug, not load
            raise ValueError(f"no alive instance can serve model {g.model}")
        wl = g.workload_profile()

        def drain(i):
            theta = i.hw(g.model).throughput(wl)
            backlog = i.virtual_queue.pending_requests() + len(g.pending())
            swap = 0.0 if i.current_model in (None, g.model) \
                else i.hw(g.model).swap_time
            return backlog * wl.mu_output / theta + swap

        inst = min(candidates, key=drain)
        inst.virtual_queue.groups.append(g)

    # ------------------------------------------------------------------
    def reschedule(self, now: float):
        """Re-solve over the ALIVE instances only: dead VQs were emptied
        at mark_dead() and must stay empty."""
        self.gc_groups()
        self._last_reschedule = now
        return self.scheduler.schedule(self.groups, self.alive_instances(),
                                       now)

    def tick(self, now: float) -> bool:
        """Periodic violation check (returns True if it rescheduled).

        Respects ``reschedule_cooldown`` like the submit path: under
        sustained overload ``predict_violation`` stays true on every tick,
        and re-solving each time churns the VQ orders (each re-solve moves
        group heads, firing the agents' head-change eviction LSO) without
        any new information to act on.
        """
        self.check_heartbeats(now)
        if now - self._last_reschedule < self.cfg.reschedule_cooldown:
            self._check_invariants()
            return False
        rescheduled = False
        if self.scheduler.predict_violation(self.alive_instances(), now):
            self.reschedule(now)
            rescheduled = True
        self._check_invariants()
        return rescheduled

    _inv_sampler = None

    def _check_invariants(self) -> None:
        """Tick-boundary hook: queue-layer state (group placement, member
        ownership) is only quiescent between scheduler actions."""
        if not self.cfg.debug_invariants:
            from repro.analysis.invariants import invariants_enabled
            if not invariants_enabled():
                return
        if self._inv_sampler is None:
            from repro.analysis.invariants import InvariantSampler
            self._inv_sampler = InvariantSampler()
        if self._inv_sampler.due():
            from repro.analysis.invariants import (check_queue_layer,
                                                   check_terminal_states)
            check_queue_layer(self, where="controller.tick")
            check_terminal_states(self, engines=self._engines,
                                  where="controller.tick")

    def gc_groups(self) -> None:
        self.groups = [g for g in self.groups if not g.done()]
        still = []
        for r in self.global_queue:
            (self.finished if r.finished() else still).append(r)
        self.global_queue = still

    # ------------------------------------------------------------------
    def all_requests(self) -> List[Request]:
        return self.finished + self.global_queue

    def slo_attainment(self, now: Optional[float] = None) -> float:
        """Fraction of SCORED requests that met their TTFT SLO.

        Scored = served requests (TTFT recorded) + definite misses that
        never got a first token: admission rejections, shed/expired
        requests, and — when ``now`` is given — requests still queued past
        their deadline (stranded).  Counting only TTFT-recorded requests
        silently inflates attainment exactly when the system is dropping
        or stranding traffic.  Client cancellations without a first token
        are excluded (the client walked away; the system didn't fail it)
        unless the deadline had already passed.
        """
        scored = hits = 0
        for r in self.all_requests() + self.rejected:
            # failed-quarantined is checked FIRST: a poison request may
            # have produced an in-SLO first token before killing its
            # engines — it still failed the client (unconditional miss)
            if r.failed:
                scored += 1
                continue
            met = r.slo_met()
            if met is not None:
                scored += 1
                hits += int(met)
                continue
            # no first token ever recorded
            if r.rejected or r.expired or r.shed:
                scored += 1          # dropped without service: miss
            elif now is not None and now > r.deadline:
                scored += 1          # past deadline and still unstarted: miss
        if scored == 0:
            return 1.0
        return hits / scored
