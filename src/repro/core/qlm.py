"""QLM controller: global queue + group formation + violation-triggered
global scheduling (paper §3 lifecycle).

Works against either the real engine cluster (``repro.serving`` +
``core.lso.QLMAgent``) or the discrete-event simulator (``repro.sim``);
both expose instances as ``core.global_scheduler.InstanceInfo``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
from typing import Callable, List, Optional, Sequence

from repro.core import routing
from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.request import Request
from repro.core.request_group import (RequestGroup, classify_into_groups,
                                      create_request_groups)
from repro.core.rwt_estimator import RWTEstimator


@dataclasses.dataclass
class QLMConfig:
    avg_batch_size: float = 32.0
    delta: float = 4.0            # request-group size multiple (§8.3: δ=4)
    z_conservative: float = 1.0   # RWT tail factor
    # Placement policy: "solver" = per-group MILP/local-search placement
    # (core/solver.py via GlobalScheduler), "slice" = slice-level
    # load balancing (core/routing.py): groups re-partitioned into
    # slices of <= slice_size requests, each placed by estimated
    # earliest finish.  slice_size None means one engine batch quantum
    # (avg_batch_size).
    routing: str = "solver"
    slice_size: Optional[int] = None
    reschedule_on_arrival: bool = True
    # min sim-seconds between solver invocations: the paper runs the global
    # scheduler OFF the critical path ("overheads can be hidden", §8.3), so
    # back-to-back arrivals share one reordering.
    reschedule_cooldown: float = 2.0
    # Run repro.analysis.invariants.check_queue_layer at every tick()
    # (group placement/ownership, SLO-min, model homogeneity).  Also
    # forced on by QLINT_INVARIANTS=1.  Debug aid.
    debug_invariants: bool = False
    # -- fault tolerance (§4: the global queue survives engine death) -----
    # Redelivery attempts per request after its serving engine dies; the
    # (budget+1)-th death quarantines the request as FAILED — the poison
    # policy: a request that kills retry_budget+1 engines stops being
    # retried instead of crash-looping the cluster.
    retry_budget: int = 2
    # Exponential backoff for redelivered requests:
    # min(cap, base * 2**(n-1)) seconds after the nth redelivery.
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    # Missed-heartbeat supervision: None disables (sparse-tick callers,
    # e.g. unit tests driving tick() manually, must not read as silence).
    # An instance is DEGRADED after missing `degraded_after_missed`
    # windows and DEAD after `dead_after_missed`.
    heartbeat_timeout_s: Optional[float] = None
    degraded_after_missed: int = 1
    dead_after_missed: int = 3
    # Consecutive transient (non-fatal) engine errors before the
    # supervisor gives up on the instance; any successful heartbeat
    # resets the strike counter.
    transient_strikes: int = 3
    # -- round watchdog (hang detection) ------------------------------
    # Success-only heartbeats cannot see a hung engine: a wedged round
    # returns cleanly having done nothing, so the agent keeps
    # heartbeating forever.  The watchdog instead tracks PROGRESS: an
    # instance that has work (resident slots or pending VQ entries) but
    # whose engine counters stay flat past its per-round deadline is
    # DEGRADED, and past `hang_dead_factor` deadlines is mark_dead like
    # a crash.  The deadline derives from the calibrated
    # HardwareProfile: worst-case healthy round = prefill_time +
    # decode_burst * decode_per_token + swap_time, times
    # `hang_grace_rounds`.  None disables (sparse-tick callers, e.g.
    # unit tests driving tick() manually).
    hang_grace_rounds: Optional[float] = None
    hang_dead_factor: float = 3.0


# Instance health states (supervision state machine — see
# docs/fault_tolerance.md).  DEAD and DRAINED are terminal for the
# INSTANCE (a crashed engine's pool is gone; a drained one was
# decommissioned on purpose) but not for the cluster:
# replace_instance() attaches a fresh engine in the departed slot.
HEALTHY = "healthy"
DEGRADED = "degraded"
DRAINING = "draining"   # decommissioning: residents finish, pulls stop
DRAINED = "drained"     # decommissioned cleanly (pool empty, not lost)
DEAD = "dead"


def _locked(method):
    """Serialize a controller entry point on ``self.lock``.

    The lock is an RLock, so locked entry points freely call each other
    (``mark_dead`` -> ``reschedule`` -> ``gc_groups``).  Lock ORDER with
    the per-engine locks: an agent thread acquires its ``engine.lock``
    FIRST and the controller lock second (``engine.pull_source`` fires
    mid-round); the controller thread therefore only ever takes engine
    locks NON-blocking / bounded (``_engine_guard``) while holding this
    one, so the cross order cannot deadlock — worst case is a bounded
    stall, after which the controller proceeds best-effort."""
    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        with self.lock:
            return method(self, *args, **kwargs)
    return wrapper


@contextlib.contextmanager
def _engine_guard(engine, timeout: float = 0.0):
    """Bounded acquire of an engine's round lock from the CONTROLLER side
    (never block indefinitely: the agent thread holding it may itself be
    waiting on the controller lock — the one cross-order that could
    deadlock).  Tri-state yield:

      * ``True``  — lock taken; engine state may be mutated safely.
      * ``None``  — the engine has no lock (single-threaded drivers,
        lockless sim engines): proceed unguarded, nothing races.
      * ``False`` — CONTENDED MISS: an agent thread is mid-round
        (typically blocked on the controller lock inside ``_pull``).
        The caller must NOT touch engine slots/pools — mutating them
        under a live round corrupts it.  Defer the work and retry from
        ``tick`` once the round finishes.
    """
    lock = getattr(engine, "lock", None)
    if lock is None:
        yield None
        return
    got = lock.acquire(timeout=timeout) if timeout > 0 \
        else lock.acquire(blocking=False)
    try:
        yield got
    finally:
        if got:
            lock.release()


@dataclasses.dataclass
class InstanceHealth:
    state: str = HEALTHY
    last_heartbeat: Optional[float] = None
    strikes: int = 0              # consecutive transient errors
    missed: int = 0               # consecutive missed heartbeat windows
    died_at: Optional[float] = None
    cause: Optional[str] = None
    # round-watchdog progress tracking: the engine-counter fingerprint
    # last seen and when it last moved (None = never sampled)
    progress_marker: Optional[tuple] = None
    last_progress: Optional[float] = None


class QLMController:
    def __init__(self, instances: Sequence[InstanceInfo],
                 cfg: Optional[QLMConfig] = None, seed: int = 0):
        self.cfg = cfg or QLMConfig()
        if self.cfg.routing not in routing.ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {self.cfg.routing!r}; "
                f"expected one of {routing.ROUTING_POLICIES}")
        # Guards the whole queue layer (global_queue, groups, VQ group
        # lists, health, scheduler state) against concurrent agent
        # threads: every public entry point is @_locked, and threaded
        # agents take this lock around ``_pull``/``sync`` (see
        # ``QLMAgent.queue_lock``), so FCFS pops and ``not_before``
        # redelivery gates stay race-free.  Reentrant: entry points
        # compose.  Single-threaded drivers pay one uncontended acquire.
        self.lock = threading.RLock()
        self.instances = list(instances)
        self.estimator = RWTEstimator(self.cfg.z_conservative)
        self.scheduler = GlobalScheduler(self.estimator, seed=seed)
        # the global queue: single-replica request store (RabbitMQ stand-in,
        # §4 Fault Tolerance) — virtual queues only hold group pointers.
        self.global_queue: List[Request] = []
        self.groups: List[RequestGroup] = []
        self.finished: List[Request] = []
        # requests 429'd before entering the global queue (admission control
        # / backpressure): never scheduled, but they COUNT as SLO misses —
        # attainment over admitted requests only would reward rejecting
        # everything hard to serve
        self.rejected: List[Request] = []
        # requests quarantined after exhausting their redelivery budget or
        # losing every instance that could serve their model (poison
        # policy).  Observability list only: the requests themselves stay
        # in global_queue/finished (stamped terminal), so attainment
        # iterates them exactly once via all_requests().
        self.failed: List[Request] = []
        # supervision: per-instance health, index-aligned with
        # self.instances (the simulator rebuilds InstanceInfo views but
        # keeps the order)
        self.health: List[InstanceHealth] = [InstanceHealth()
                                             for _ in self.instances]
        self.redeliveries = 0        # total redelivery events (stats)
        self.routing_invocations = 0  # slice_schedule runs (routing="slice")
        # engine-touching LSOs deferred on a contended engine guard
        # (threaded agents mid-round); retried from tick()
        self._pending_salvage: List = []      # [(idx, engine), ...]
        self._pending_evicts: dict = {}       # idx -> (engine, evict)
        # lifecycle stats (self-healing cluster: see docs/fault_tolerance.md)
        self.hangs = 0               # watchdog-detected hangs (mark_dead'd)
        self.drains = 0              # drain_instance invocations
        self.replacements = 0        # replace_instance invocations
        self.migrations = 0          # snapshots made portable cross-engine
        # optional engine handles, index-aligned with instances: lets
        # mark_dead() reclaim a dead engine's resident requests and lets
        # the terminal-state invariant cross-check engine residency
        self._engines: Optional[List] = None
        self._last_reschedule = -math.inf

    # -- supervision -------------------------------------------------------
    @_locked
    def attach_engines(self, engines: Sequence) -> None:
        """Register the engine behind each instance (order-aligned with
        ``instances``).  Optional: without it, mark_dead() can only sweep
        queue-visible state (``_served_by`` / snapshots)."""
        assert len(engines) == len(self.instances), \
            (len(engines), len(self.instances))
        self._engines = list(engines)

    def is_alive(self, idx: int) -> bool:
        """Alive = the engine process exists and may hold resident work.
        DRAINING counts (its residents are finishing); DEAD and DRAINED
        do not (the instance departed)."""
        return self.health[idx].state not in (DEAD, DRAINED)

    def is_schedulable(self, idx: int) -> bool:
        """Schedulable = NEW work may be placed on it.  Stricter than
        alive: a DRAINING instance finishes its residents but its VQ
        stays empty — it is departing capacity."""
        return self.health[idx].state in (HEALTHY, DEGRADED)

    def alive_instances(self) -> List[InstanceInfo]:
        return [inst for i, inst in enumerate(self.instances)
                if self.is_alive(i)]

    def schedulable_instances(self) -> List[InstanceInfo]:
        return [inst for i, inst in enumerate(self.instances)
                if self.is_schedulable(i)]

    def alive_fraction(self) -> float:
        if not self.instances:
            return 0.0
        return len(self.alive_instances()) / len(self.instances)

    def serving_fraction(self) -> float:
        """Fraction of attached instances new work can land on (excludes
        dead, drained, AND draining — the front end scales its admission
        limits by this, so departing capacity sheds load 503-style
        instead of stranding it).  0.0 with zero attached instances."""
        if not self.instances:
            return 0.0
        return len(self.schedulable_instances()) / len(self.instances)

    def can_serve(self, model: str) -> bool:
        """Does any SCHEDULABLE instance serve ``model``?  (A model whose
        only server is draining is already unservable for new work.)"""
        return any(model in i.hw_by_model
                   for i in self.schedulable_instances())

    @_locked
    def heartbeat(self, idx: int, now: float) -> None:
        """A successful agent iteration: reset the strike/missed counters
        and recover a DEGRADED instance (DEAD/DRAINED stay departed — the
        instance is gone; recovery means attaching a new one.  DRAINING
        stays draining: heartbeats prove liveness, not capacity)."""
        h = self.health[idx]
        if not self.is_alive(idx):
            return
        h.last_heartbeat = now
        h.strikes = 0
        h.missed = 0
        if h.state == DEGRADED:
            h.state = HEALTHY

    @_locked
    def check_heartbeats(self, now: float) -> None:
        """Tick-side liveness: an instance whose agent has not heartbeated
        for ``heartbeat_timeout_s`` misses windows; enough misses degrade
        then kill it (a wedged engine strands its whole virtual queue)."""
        timeout = self.cfg.heartbeat_timeout_s
        if timeout is None:
            return
        for idx, h in enumerate(self.health):
            if not self.is_alive(idx):
                continue
            if h.last_heartbeat is None:
                h.last_heartbeat = now   # start the window at first sight
                continue
            h.missed = int((now - h.last_heartbeat) // timeout)
            if h.missed >= self.cfg.dead_after_missed:
                self.mark_dead(idx, now, cause=(
                    f"missed {h.missed} heartbeat window(s) of {timeout}s"))
            elif h.missed >= self.cfg.degraded_after_missed \
                    and h.state == HEALTHY:
                h.state = DEGRADED

    # -- round watchdog (hang detection) -------------------------------
    def round_deadline(self, idx: int) -> Optional[float]:
        """Worst-case seconds a HEALTHY round on instance ``idx`` may
        take, derived from its calibrated HardwareProfile(s): one full
        prefill admission + a fused decode burst + a model swap.  None
        when the instance carries no profile (nothing to calibrate
        against)."""
        hws = list(self.instances[idx].hw_by_model.values())
        if not hws:
            return None
        return max(hw.prefill_time
                   + hw.decode_per_token * max(1, getattr(hw, "decode_burst",
                                                          1))
                   + hw.swap_time for hw in hws)

    @staticmethod
    def _progress_marker(engine) -> Optional[tuple]:
        """Monotone fingerprint of engine work: any dispatched round that
        did something moves at least one component.  ``lengths`` covers
        mid-prefill chunk progress (no counter bumps until the first
        token lands)."""
        stats = getattr(engine, "stats", None)
        if stats is None:
            return None
        marker = tuple(int(getattr(stats, f, 0)) for f in (
            "tokens_generated", "prefills", "prefill_chunks", "evictions",
            "resumes", "model_swaps", "cancellations"))
        lengths = getattr(engine, "lengths", None)
        if lengths is not None:
            marker += (int(sum(int(x) for x in lengths)),)
        return marker

    def _instance_busy(self, idx: int, engine) -> bool:
        num_active = getattr(engine, "num_active", None)
        if num_active is not None and num_active() > 0:
            return True
        vq = self.instances[idx].virtual_queue
        return vq.pending_requests() > 0

    @_locked
    def check_watchdog(self, now: float) -> None:
        """Per-round-deadline hang detection.  Heartbeats only fire on
        success, and a hung engine's rounds SUCCEED (they just do
        nothing) — so liveness here is defined as progress: an instance
        with work whose engine counters stay flat for more than
        ``hang_grace_rounds`` round deadlines is DEGRADED; past
        ``hang_dead_factor`` times that it is mark_dead exactly like a
        crash (abandon + redeliver + re-solve)."""
        grace = self.cfg.hang_grace_rounds
        if grace is None or self._engines is None:
            return
        for idx, h in enumerate(self.health):
            if not self.is_alive(idx):
                continue
            engine = self._engines[idx]
            if engine is None:
                continue
            marker = self._progress_marker(engine)
            if marker is None:
                continue
            if marker != h.progress_marker or h.last_progress is None \
                    or not self._instance_busy(idx, engine):
                h.progress_marker = marker
                h.last_progress = now
                continue
            deadline = self.round_deadline(idx)
            if deadline is None:
                continue
            stalled = now - h.last_progress
            budget = grace * deadline
            if stalled > budget * self.cfg.hang_dead_factor:
                self.hangs += 1
                self.mark_dead(idx, now, cause=(
                    f"hang: busy but no progress for {stalled:.3f}s "
                    f"(> {self.cfg.hang_dead_factor:g} x {budget:.3f}s "
                    f"round-watchdog budget)"))
            elif stalled > budget and h.state == HEALTHY:
                h.state = DEGRADED

    @_locked
    def report_engine_failure(self, idx: int, exc: BaseException, now: float,
                              engine=None) -> str:
        """Agent-exception supervision: fatal failures (``EngineCrashed`` /
        ``EngineDead`` — ``exc.fatal``) kill the instance immediately;
        transient errors strike it (DEGRADED) until
        ``cfg.transient_strikes`` consecutive strikes give up on it.
        Returns the resulting health state."""
        h = self.health[idx]
        if not self.is_alive(idx):
            return h.state
        if engine is not None and self._engines is not None:
            self._engines[idx] = engine
        if getattr(exc, "fatal", False):
            self.mark_dead(idx, now, cause=repr(exc), engine=engine)
            return DEAD
        h.strikes += 1
        if h.strikes >= self.cfg.transient_strikes:
            self.mark_dead(idx, now, cause=(
                f"{h.strikes} consecutive transient errors "
                f"(last: {exc!r})"), engine=engine)
            return DEAD
        h.state = DEGRADED
        return DEGRADED

    def backoff(self, n: int) -> float:
        """Redelivery backoff after the nth delivery failure (n >= 1):
        exponential, capped."""
        return min(self.cfg.backoff_cap_s,
                   self.cfg.backoff_base_s * (2.0 ** (n - 1)))

    @_locked
    def mark_dead(self, idx: int, now: float, cause: str = "killed",
                  engine=None) -> None:
        """Quarantine instance ``idx`` and recover its work (§4 fault
        tolerance: requests live in the global queue, virtual queues hold
        pointers — so losing an engine loses no request):

          1. the dead VQ is emptied (groups are pointers; the requests
             are still in the global queue);
          2. the engine's resident requests (slots + pushback limbo) are
             abandoned — KV accounting freed host-side, nothing stamped
             terminal — and redelivered with backoff;
          3. snapshots pinned in the dead pool are discarded (pins
             released so the dead BlockManager's accounting stays
             conserved) and their requests restart cleanly;
          4. requests whose model no longer has an alive instance are
             quarantined as recorded misses;
          5. surviving groups are re-placed on alive instances and the
             scheduler re-solves without the dead one.
        """
        h = self.health[idx]
        if h.state in (DEAD, DRAINED):
            return
        h.state = DEAD
        h.died_at = now
        h.cause = cause
        if engine is None and self._engines is not None:
            engine = self._engines[idx]
        dead_inst = self.instances[idx]
        dead_inst.virtual_queue.groups.clear()
        # 2.-5. need the engine quiescent: a contended miss means the
        # agent thread is MID-ROUND (usually blocked on our lock inside
        # ``_pull``) — abandoning slots or redelivering its residents now
        # would corrupt the live round / double-serve its requests.  The
        # instance is already DEAD, so the agent parks after this round
        # and the deferred salvage succeeds on the next tick.
        with _engine_guard(engine, timeout=1.0) as got:
            if got is False:
                self._pending_salvage.append((idx, engine))
                return
            self._salvage_dead(idx, engine, now)
        self._check_invariants()

    def _salvage_dead(self, idx: int, engine, now: float) -> None:
        """Steps 2.-5. of ``mark_dead`` (caller holds the engine guard —
        or the engine is lockless / known parked)."""
        dead_pool = getattr(engine, "block_mgr", None)
        # 2. reclaim engine-resident requests (crash salvage): KV
        # accounting freed host-side, nothing stamped terminal
        if engine is not None and hasattr(engine, "abandon"):
            for r in engine.abandon():
                if not r.finished():
                    self._redeliver(r, now)
        # 3./4. sweep the global queue: dead-pool snapshots, stragglers
        # still tagged as served by the dead instance, unservable models
        for r in list(self.global_queue):
            if r.finished():
                continue
            snap = r.snapshot
            if snap is not None and isinstance(snap, dict) \
                    and snap.get("pin_owner") is not None \
                    and snap.get("pin_owner") is dead_pool:
                # pinned in the dead pool: the pinned pages died with the
                # engine — release the pins (conserves the dead pool's
                # accounting) and restart from the prompt
                if snap.get("pinned"):
                    snap["pin_owner"].release_pins(snap["pinned"],
                                                   snap.get("pin_epoch"))
                r.restart()
            if getattr(r, "_served_by", None) == idx \
                    and getattr(r, "_in_flight", False):
                self._redeliver(r, now)
            if not r.finished() and not self.can_serve(r.model):
                self._quarantine(r, now, f"model {r.model} unservable "
                                         f"after instance {idx} died")
        # 5. re-place orphaned groups, then re-solve over the survivors
        self.gc_groups()
        for g in self.groups:
            if not g.done() and not self._placed(g):
                self._place_new_group(g, now)
        if self.schedulable_instances():
            self.reschedule(now)
            # cross-engine migration: re-placed requests whose eviction
            # snapshots are pinned in some OTHER alive pool must become
            # portable, or their new server refuses them forever
            self.migration_sweep(now)

    def _redeliver(self, r: Request, now: float) -> None:
        """Return an in-flight request to the (still-placed) global queue
        with retry budget + exponential backoff."""
        r._in_flight = False
        r._served_by = None
        r.redeliveries += 1
        if r.redeliveries > self.cfg.retry_budget:
            self._quarantine(r, now, f"retry budget exhausted after "
                                     f"{r.redeliveries} deliveries")
            return
        self.redeliveries += 1
        not_before = now + self.backoff(r.redeliveries)
        if r.first_token_time is None and not_before > r.deadline:
            # the backoff window already overshoots the TTFT deadline:
            # quarantine as a miss NOW instead of leaving the request
            # sitting unpullable in the queue until it expires (same
            # score, immediate terminal state — no zombie queue entries)
            self._quarantine(r, now, (
                f"redelivery backoff to t={not_before:.3f} overshoots "
                f"deadline t={r.deadline:.3f}"))
            return
        r.not_before = not_before
        if r.snapshot is None and (r.generated > 0 or r._prefill_done > 0):
            # generation state died with the engine and no snapshot
            # survived: restart cleanly (first_token_time kept — never
            # double-counted in attainment; see Request.restart)
            r.restart()

    def _quarantine(self, r: Request, now: float, cause: str) -> None:
        """Poison/unservable terminal state: a recorded SLO miss.  The
        request is stamped finished so group cursors skip it and gc moves
        it to ``finished``; ``failed`` makes attainment score it a miss
        even if a pre-crash first token landed in time."""
        r.failed = True
        r.fail_cause = cause
        r._in_flight = False
        r._served_by = None
        snap = r.snapshot
        if snap is not None and isinstance(snap, dict) and snap.get("pinned") \
                and snap.get("pin_owner") is not None:
            snap["pin_owner"].release_pins(snap["pinned"],
                                           snap.get("pin_epoch"))
        r.snapshot = None
        if r.completion_time is None:
            r.completion_time = now
        self.failed.append(r)

    # -- graceful drain + replacement (self-healing lifecycle) ----------
    @_locked
    def drain_instance(self, idx: int, now: float, *, evict: bool = False,
                       cause: str = "drain") -> None:
        """Graceful-decommission LSO: stop pulling new work onto instance
        ``idx``, hand its queued work to the survivors, and let the
        resident decodes finish (``evict=True`` evicts them instead —
        snapshots migrate and resume elsewhere).  The instance stays
        DRAINING (alive, residents finishing, no pulls) until ``tick``
        observes an empty engine and decommissions it to DRAINED."""
        h = self.health[idx]
        if h.state not in (HEALTHY, DEGRADED):
            return
        h.state = DRAINING
        h.cause = cause
        self.drains += 1
        inst = self.instances[idx]
        inst.virtual_queue.groups.clear()
        engine = self._engines[idx] if self._engines is not None else None
        if engine is not None:
            # bounded engine-lock wait: the draining engine's agent
            # thread is still running rounds (residents finish in place).
            # A contended miss means the agent is mid-round — evicting
            # its slots now would corrupt the round, so the evict defers
            # to the next tick (the round finishes, the lock frees).
            with _engine_guard(engine, timeout=1.0) as got:
                if got is False:
                    self._pending_evicts[idx] = (engine, evict)
                else:
                    self._drain_evict(engine, evict)
        # queued work that just lost its last schedulable server is a
        # recorded miss (residents still finish on the draining engine)
        for r in list(self.global_queue):
            if not r.finished() and not getattr(r, "_in_flight", False) \
                    and not self.can_serve(r.model):
                self._quarantine(r, now, f"model {r.model} unservable "
                                         f"while instance {idx} drains")
        self.gc_groups()
        for g in self.groups:
            if g.done() or self._placed(g):
                continue
            if self.can_serve(g.model):
                self._place_new_group(g, now)
            else:
                # residents-only remnant (members in flight on the
                # draining engine): keep it reachable here — nothing in
                # it is pullable, and _finish_drains reconciles the rest
                inst.virtual_queue.groups.append(g)
        if self.schedulable_instances():
            self.reschedule(now)
            self.migration_sweep(now)
        self._check_invariants()

    def _drain_evict(self, engine, evict: bool) -> None:
        """Engine-touching half of ``drain_instance`` (caller holds the
        engine guard — or the engine is lockless)."""
        if evict and hasattr(engine, "evict_slot"):
            for slot in list(engine.active_slots()):
                r = engine.evict_slot(slot)
                r._in_flight = False
                r._served_by = None
            pushed = engine.take_pushback()
            if pushed is not None:
                pushed._in_flight = False
                pushed._served_by = None
        # departing capacity must not hold anyone's prefix pages:
        # promote every snapshot pinned in this pool to portable form
        # now, so the requests resume on OTHER engines (cross-engine
        # migration) instead of waiting out the drain
        pinned_here = [r for r in getattr(engine, "_pinned_snapshots", ())
                       if r.snapshot is not None
                       and r.snapshot.get("pinned")]
        if pinned_here:
            engine._materialize_pinned_snapshots()
            self.migrations += len(pinned_here)

    @_locked
    def _retry_deferred(self, now: float) -> None:
        """Tick-side retry of engine-touching LSOs that hit a contended
        engine guard (the agent was mid-round when ``mark_dead`` /
        ``drain_instance`` ran).  Dead/draining agents park or finish
        their round quickly, so these drain within a tick or two."""
        if self._pending_salvage:
            still = []
            for idx, engine in self._pending_salvage:
                with _engine_guard(engine) as got:
                    if got is False:
                        still.append((idx, engine))
                        continue
                    self._salvage_dead(idx, engine, now)
            self._pending_salvage = still
        for idx in list(self._pending_evicts):
            engine, evict = self._pending_evicts[idx]
            if self.health[idx].state != DRAINING:
                # the drain resolved some other way (e.g. the instance
                # died outright and was salvaged)
                del self._pending_evicts[idx]
                continue
            with _engine_guard(engine) as got:
                if got is False:
                    continue
                self._drain_evict(engine, evict)
            del self._pending_evicts[idx]
            # evicted members are pullable again, but their groups may be
            # parked on the (non-schedulable) draining VQ as residents-
            # only remnants: re-place them on the survivors
            self.instances[idx].virtual_queue.groups.clear()
            self.gc_groups()
            for g in self.groups:
                if g.done() or self._placed(g):
                    continue
                if self.can_serve(g.model):
                    self._place_new_group(g, now)
                else:
                    for r in g.requests:
                        if not r.finished():
                            self._quarantine(r, now, (
                                f"model {r.model} unservable after "
                                f"deferred evict on instance {idx}"))
            if self.schedulable_instances():
                self.reschedule(now)
                self.migration_sweep(now)

    @_locked
    def _finish_drains(self, now: float) -> None:
        """Decommission DRAINING instances whose engines emptied out:
        state -> DRAINED, VQ cleared, any member a late pushback left
        queued here re-placed (or quarantined if its model lost its last
        server)."""
        for idx, h in enumerate(self.health):
            if h.state != DRAINING:
                continue
            engine = self._engines[idx] if self._engines is not None \
                else None
            if engine is not None:
                if getattr(engine, "num_active", lambda: 0)() > 0:
                    continue
                if getattr(engine, "_pushback", None) is not None:
                    continue
            h.state = DRAINED
            h.died_at = now
            self.instances[idx].virtual_queue.groups.clear()
            self.gc_groups()
            for g in self.groups:
                if g.done() or self._placed(g):
                    continue
                if self.can_serve(g.model):
                    self._place_new_group(g, now)
                else:
                    for r in g.requests:
                        if not r.finished():
                            self._quarantine(r, now, (
                                f"model {r.model} unservable after "
                                f"instance {idx} drained"))
            self._check_invariants()

    @_locked
    def replace_instance(self, idx: int, engine, now: float,
                         hw_by_model=None, model_name=None) -> None:
        """Attach a fresh engine in a departed slot: DEAD/DRAINED stops
        being terminal for the CLUSTER, only for the instance that died.
        The virtual queue is reused (it holds pointers, and it was
        emptied when the predecessor departed), health resets to
        HEALTHY, and a re-solve spreads queued + redelivered work onto
        the recovered capacity."""
        h = self.health[idx]
        if h.state not in (DEAD, DRAINED):
            raise ValueError(
                f"instance {idx} is {h.state}: only departed "
                f"(dead/drained) instances can be replaced")
        # flush any salvage still deferred for this slot BEFORE the new
        # engine takes it: the retry keys requests on ``_served_by ==
        # idx``, which would resolve to the REPLACEMENT after this point.
        # The departed agent is parked, so the bounded wait succeeds; on
        # a pathological miss salvage proceeds unguarded — the old
        # engine is being discarded either way.
        for i, old_engine in [p for p in self._pending_salvage
                              if p[0] == idx]:
            with _engine_guard(old_engine, timeout=1.0):
                self._salvage_dead(i, old_engine, now)
        self._pending_salvage = [p for p in self._pending_salvage
                                 if p[0] != idx]
        self._pending_evicts.pop(idx, None)
        inst = self.instances[idx]
        inst.virtual_queue.groups.clear()
        if hw_by_model is not None:
            inst.hw_by_model = dict(hw_by_model)
        inst.current_model = model_name if model_name is not None \
            else getattr(engine, "model_name", inst.current_model)
        if self._engines is None:
            self._engines = [None] * len(self.instances)
        self._engines[idx] = engine
        self.health[idx] = InstanceHealth(last_heartbeat=now)
        self.replacements += 1
        self.reschedule(now)
        self.migration_sweep(now)
        self._check_invariants()

    # -- cross-engine snapshot migration --------------------------------
    def _pool_owner(self, pool) -> Optional[int]:
        """Index of the ALIVE attached engine whose current pool is
        ``pool`` (None: the pool died, was swapped out, or is foreign)."""
        if pool is None or self._engines is None:
            return None
        for idx, eng in enumerate(self._engines):
            if eng is not None and self.is_alive(idx) \
                    and getattr(eng, "block_mgr", None) is pool:
                return idx
        return None

    @_locked
    def migration_sweep(self, now: float) -> int:
        """Make stranded-by-pinning snapshots portable (the recovery half
        of the eviction LSO).  A request whose snapshot pins shared-
        prefix pages in pool A can only resume on A's engine; when the
        scheduler placed it elsewhere (death, drain, or rebalance), ask
        the OWNING engine to materialize the snapshot — pinned page
        contents copied into it, pins released — after which any alive
        engine of the same KV layout resumes it token-identically.
        Pins whose owner departed or reset its pool are released (the
        pages are gone) and the request restarts from its prompt.
        Returns the number of snapshots migrated."""
        if self._engines is None:
            return 0
        placed = {}
        for idx, inst in enumerate(self.instances):
            for g in inst.virtual_queue.groups:
                placed[g.group_id] = idx
        migrated = 0
        for r in self.global_queue:
            if r.finished() or getattr(r, "_in_flight", False):
                continue
            snap = r.snapshot
            if not isinstance(snap, dict) or not snap.get("pinned"):
                continue
            pool = snap.get("pin_owner")
            owner = self._pool_owner(pool)
            if owner is None \
                    or snap.get("pin_epoch") != getattr(pool, "epoch", None):
                # the pinned pages no longer exist: release (stale-epoch
                # release is a no-op) and recompute from the prompt
                pool.release_pins(snap["pinned"], snap.get("pin_epoch"))
                r.restart()
                continue
            home = placed.get(r.group_id)
            if home == owner and self.is_schedulable(owner):
                continue   # its own engine will resume it: pins transfer
            engine = self._engines[owner]
            if not hasattr(engine, "materialize_snapshot"):
                continue
            # non-blocking: the owner's agent may be mid-round — skip
            # this snapshot and retry on the next tick's sweep rather
            # than stall the controller (``got`` is False only when a
            # REAL lock was busy; lockless engines proceed unguarded)
            with _engine_guard(engine) as got:
                if got is False:
                    # real lock busy (agent mid-round): skip this sweep
                    # rather than stall the controller; lockless engines
                    # yield None and proceed unguarded
                    continue
                if engine.materialize_snapshot(r):
                    migrated += 1
                    self.migrations += 1
        return migrated

    @property
    def max_group(self) -> int:
        return max(1, int(self.cfg.avg_batch_size * self.cfg.delta))

    # ------------------------------------------------------------------
    @_locked
    def submit(self, req: Request, now: float) -> bool:
        """API-gateway entry: enqueue, classify into a group, reschedule if
        the RWT estimator predicts a violation.

        When NO alive instance can serve ``req.model`` the request is
        recorded as a 400-style rejection (an attainment miss) and
        ``False`` is returned — once, here, instead of raising out of the
        serve path (one bad request must not kill the loop) or letting
        ``predict_violation`` report an unfixable violation every
        cooldown tick (solver thrash)."""
        if not self.can_serve(req.model):
            self.record_rejection(req, now)
            return False
        self.global_queue.append(req)
        g = classify_into_groups(req, self.groups, max_group=self.max_group)
        if g is None:
            g = RequestGroup(model=req.model, slo=req.slo)
            g.add(req)
            self.groups.append(g)
            self._place_new_group(g, now)
        elif not self._placed(g):
            # liveness: the group existed but is reachable from no instance
            # (an infeasible-solve set_order/_edf_fallback dropped it, or a
            # VQ popped it while momentarily done) — without re-placement
            # the new request would strand in the global queue until an
            # unrelated violation triggers a full reschedule
            self._place_new_group(g, now)
        if self.cfg.reschedule_on_arrival and \
                now - self._last_reschedule >= self.cfg.reschedule_cooldown and \
                self.scheduler.predict_violation(self.schedulable_instances(),
                                                 now):
            self.reschedule(now)
        return True

    @_locked
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        """Bulk arrival: form groups with Algorithm 1 k-means, then solve."""
        self.global_queue.extend(requests)
        new_groups = create_request_groups(
            requests, avg_batch_size=self.cfg.avg_batch_size,
            delta=self.cfg.delta)
        self.groups.extend(new_groups)
        self.reschedule(now)

    def _placed(self, g: RequestGroup) -> bool:
        """Is ``g`` reachable from at least one instance's virtual queue?"""
        return any(g is q for inst in self.instances
                   for q in inst.virtual_queue.groups)

    @_locked
    def record_rejection(self, req: Request, now: float) -> None:
        """Admission-control / backpressure rejection (§9 option (c)):
        the request never enters the global queue, but attainment
        accounting must still see it as a miss."""
        req.rejected = True
        if req.completion_time is None:
            req.completion_time = now
        self.rejected.append(req)

    def _place_new_group(self, g: RequestGroup, now: float) -> None:
        """Cheap placement for a singleton group (full solve happens on
        violation): minimize the RWT-estimated drain of (queue + group) —
        heterogeneity-aware (Design Principle #3: an A10 absorbs
        proportionally less work than an A100), unlike a raw request count.
        """
        candidates = [i for i in self.schedulable_instances()
                      if g.model in i.hw_by_model]
        if not candidates:
            # submit() rejects unservable models and mark_dead() /
            # drain_instance() quarantine orphans before re-placing, so
            # this is a controller bug, not load
            raise ValueError(f"no alive instance can serve model {g.model}")
        wl = g.workload_profile()

        def drain(i):
            theta = i.hw(g.model).throughput(wl)
            backlog = i.virtual_queue.pending_requests() + len(g.pending())
            swap = 0.0 if i.current_model in (None, g.model) \
                else i.hw(g.model).swap_time
            return backlog * wl.mu_output / theta + swap

        inst = min(candidates, key=drain)
        inst.virtual_queue.groups.append(g)

    # ------------------------------------------------------------------
    @_locked
    def reschedule(self, now: float):
        """Re-solve over the SCHEDULABLE instances only: dead/drained VQs
        were emptied when the instance departed and must stay empty, and
        a draining instance is departing capacity the solver must not
        count on."""
        self.gc_groups()
        self._last_reschedule = now
        if self.cfg.routing == "slice":
            self.routing_invocations += 1
            return routing.slice_schedule(self, now)
        return self.scheduler.schedule(self.groups,
                                       self.schedulable_instances(), now)

    @_locked
    def tick(self, now: float) -> bool:
        """Periodic violation check (returns True if it rescheduled).

        Respects ``reschedule_cooldown`` like the submit path: under
        sustained overload ``predict_violation`` stays true on every tick,
        and re-solving each time churns the VQ orders (each re-solve moves
        group heads, firing the agents' head-change eviction LSO) without
        any new information to act on.
        """
        self.check_watchdog(now)
        self.check_heartbeats(now)
        self._retry_deferred(now)
        self._finish_drains(now)
        self.migration_sweep(now)
        if now - self._last_reschedule < self.cfg.reschedule_cooldown:
            self._check_invariants()
            return False
        rescheduled = False
        if self.scheduler.predict_violation(self.schedulable_instances(),
                                            now):
            self.reschedule(now)
            rescheduled = True
        self._check_invariants()
        return rescheduled

    _inv_sampler = None

    def _check_invariants(self) -> None:
        """Tick-boundary hook: queue-layer state (group placement, member
        ownership) is only quiescent between scheduler actions.

        Thread-awareness: ``check_queue_layer`` touches only
        controller-lock-guarded state, so it always runs.  The
        engine-residency cross-checks (``check_terminal_states`` /
        ``check_migration``) read every engine's slots and pushback,
        which are only consistent at round boundaries — so they run
        only when every engine's round lock try-acquires (i.e. every
        engine is between rounds).  A busy engine defers them to the
        next tick; single-threaded drivers always acquire."""
        if not self.cfg.debug_invariants:
            from repro.analysis.invariants import invariants_enabled
            if not invariants_enabled():
                return
        if self._inv_sampler is None:
            from repro.analysis.invariants import InvariantSampler
            self._inv_sampler = InvariantSampler()
        if not self._inv_sampler.due():
            return
        from repro.analysis.invariants import (check_migration,
                                               check_queue_layer,
                                               check_terminal_states)
        if self._pending_salvage or self._pending_evicts:
            # deferred salvage/evict means the queue layer is knowingly
            # mid-transition (a dead VQ is cleared but its groups are not
            # re-placed until the retry lands, and some engine's
            # residency state is stale): skip ALL checks until then
            return
        check_queue_layer(self, where="controller.tick")
        with contextlib.ExitStack() as stack:
            quiescent = True
            for eng in (self._engines or ()):
                guard = stack.enter_context(_engine_guard(eng))
                if guard is False:
                    quiescent = False
                    break
            if quiescent:
                check_terminal_states(self, engines=self._engines,
                                      where="controller.tick")
                check_migration(self, engines=self._engines,
                                where="controller.tick")

    @_locked
    def gc_groups(self) -> None:
        self.groups = [g for g in self.groups if not g.done()]
        still = []
        for r in self.global_queue:
            (self.finished if r.finished() else still).append(r)
        self.global_queue = still

    # ------------------------------------------------------------------
    def all_requests(self) -> List[Request]:
        return self.finished + self.global_queue

    @_locked
    def slo_attainment(self, now: Optional[float] = None) -> float:
        """Fraction of SCORED requests that met their TTFT SLO.

        Scored = served requests (TTFT recorded) + definite misses that
        never got a first token: admission rejections, shed/expired
        requests, and — when ``now`` is given — requests still queued past
        their deadline (stranded).  Counting only TTFT-recorded requests
        silently inflates attainment exactly when the system is dropping
        or stranding traffic.  Client cancellations without a first token
        are excluded (the client walked away; the system didn't fail it)
        unless the deadline had already passed.
        """
        scored = hits = 0
        for r in self.all_requests() + self.rejected:
            # failed-quarantined is checked FIRST: a poison request may
            # have produced an in-SLO first token before killing its
            # engines — it still failed the client (unconditional miss)
            if r.failed:
                scored += 1
                continue
            met = r.slo_met()
            if met is not None:
                scored += 1
                hits += int(met)
                continue
            # no first token ever recorded
            if r.rejected or r.expired or r.shed:
                scored += 1          # dropped without service: miss
            elif now is not None and now > r.deadline:
                scored += 1          # past deadline and still unstarted: miss
        if scored == 0:
            return 1.0
        return hits / scored
