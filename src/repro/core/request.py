"""Request / SLO model (paper §2.3 Definitions 2.1–2.3).

A request = prompt tokens + metadata (model type, SLO).  The SLO is on
p99 time-to-first-token (TTFT).  Paper workload classes (§8):
Interactive 20 s, Batch-1 60 s, Batch-2 3600 s.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional

_req_counter = itertools.count()

# paper §8 SLO classes (seconds, p99 TTFT)
SLO_INTERACTIVE = 20.0
SLO_BATCH1 = 60.0
SLO_BATCH2 = 3600.0

SLO_CLASSES = {
    "interactive": SLO_INTERACTIVE,
    "batch1": SLO_BATCH1,
    "batch2": SLO_BATCH2,
}


@dataclasses.dataclass
class Request:
    prompt_tokens: Any                 # list[int] / np.ndarray
    model: str                         # model type the request targets
    slo: float                         # TTFT SLO in seconds
    arrival_time: float = 0.0
    max_new_tokens: int = 128
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    slo_class: str = ""
    # strict priority (§9): lower = more urgent; 0 = default
    priority: int = 0

    # lifecycle (filled by the runtime / simulator)
    group_id: Optional[int] = None
    first_token_time: Optional[float] = None
    completion_time: Optional[float] = None
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    n_evictions: int = 0
    # eviction snapshot handle (host-side KV/state copy), engine-internal
    snapshot: Any = None
    generated: int = 0
    # modality extras (VLM patch embeds / audio frame embeds), passed to prefill
    extras: Any = None
    # ground-truth output length (simulator only; unknown to the scheduler)
    true_output_tokens: Optional[int] = None
    # prompt tokens served from the shared-prefix KV cache instead of
    # prefill.  The real engine fills it at admission (observability); the
    # simulator consumes it as ground truth — like true_output_tokens —
    # to skip prefill work / KV for the shared leading run.
    prefix_shared_tokens: int = 0
    # multi-turn session bookkeeping (data.workload.Session): follow-up
    # requests re-enter the queue carrying the previous turns' tokens as a
    # prompt prefix, so the prefix index serves real session traffic
    session_id: Optional[int] = None
    turn: int = 0
    # async front-end lifecycle (serving.frontend): set by the client /
    # server, observed by the queue layer's accounting
    cancel_requested: bool = False   # client asked; server acts on next sweep
    cancelled: bool = False          # cancellation executed (KV freed)
    rejected: bool = False           # 429'd by admission control / backpressure
    expired: bool = False            # deadline passed before any dispatch
    shed: bool = False               # dropped by the SLO-pressure shedder
    # fault tolerance (§4: the global queue survives engine death):
    # redelivery count, earliest re-dispatch time (exponential backoff),
    # and the poison-quarantine terminal flag — a request whose retry
    # budget is exhausted is FAILED, a recorded SLO miss, never retried
    redeliveries: int = 0
    not_before: float = 0.0
    failed: bool = False
    fail_cause: Optional[str] = None
    # scheduling flag: currently in a running batch
    _in_flight: bool = False
    # instance id currently serving this request (set by the pulling
    # agent, cleared on every path that returns it to the queue) — the
    # supervisor uses it to find a dead engine's in-flight requests
    _served_by: Optional[int] = None
    # chunked-prefill progress kept across evictions (simulator mirror of
    # the engine's snapshot["prefill_pos"])
    _prefill_done: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def deadline(self) -> float:
        return self.arrival_time + self.slo

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def slo_met(self) -> Optional[bool]:
        t = self.ttft()
        return None if t is None else (t <= self.slo)

    def itl(self) -> Optional[float]:
        """Mean inter-token latency (§9 'Can SLOs be defined on ITL?' —
        QLM tracks it so an Andes-style ITL guard can consume it)."""
        if self.completion_time is None or self.first_token_time is None:
            return None
        if self.generated <= 1:
            return 0.0
        return (self.completion_time - self.first_token_time) / (self.generated - 1)

    def finished(self) -> bool:
        return self.completion_time is not None

    def dropped(self) -> bool:
        """Terminated without service: rejected at the door, expired past
        its deadline unstarted, shed by the overload policy, quarantined
        after exhausting its redelivery budget, or cancelled before the
        first token.  A definite SLO miss (except client cancellation,
        which is excluded from attainment accounting)."""
        return (self.rejected or self.expired or self.shed or self.failed
                or (self.cancelled and self.first_token_time is None))

    def restart(self) -> None:
        """Clean-restart for redelivery after its serving engine died with
        the generation state (no snapshot survived): generation progress
        resets so the next engine replays from the prompt.  Greedy decode
        is deterministic, so the regenerated tokens match what any client
        already streamed.  ``first_token_time`` is KEPT when already
        recorded — the first token genuinely reached the client, and
        resetting it would let a crash-and-retry double-count as a fresh
        (later, possibly SLO-missing) first token in attainment."""
        self.output_tokens.clear()
        self.generated = 0
        self._prefill_done = 0
        self.snapshot = None


def make_request(prompt_tokens, model: str, slo_class: str,
                 arrival_time: float = 0.0, max_new_tokens: int = 128) -> Request:
    return Request(prompt_tokens=prompt_tokens, model=model,
                   slo=SLO_CLASSES[slo_class], arrival_time=arrival_time,
                   max_new_tokens=max_new_tokens, slo_class=slo_class)
