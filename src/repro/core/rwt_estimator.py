"""Request Waiting Time (RWT) Estimator — paper §6 and Appendix A.1.

    C_q = W_q + P + D_q                                  (Eq. 1)
    W_q = Σ_{i<q} O_i / Θ                                (Eq. 2)
    Σ O_i ~ N((q−1)μ_o, (q−1)σ_o²)                       (Eq. 3, CLT)
    D_q = O_max · ε · d                                  (Eq. 4, conservative)
    C   = max_q C_q                                      (Eq. 5)

with the Appendix A.1 throughput model:

    Θ = B / (d · ε)          (Eq. 15)
    B ≈ GPU / E[I_i + O_i]   (Eq. 16)

Profiling inputs (paper "Offline Profiling"): a WorkloadProfile (token
distribution fitted from request history per request group) and a
HardwareProfile (P, d, ε, GPU token capacity, swap time S — one batch run
per (model, device) combination; see ``serving.engine.profile`` /
``sim.profiles``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Input/output token distribution for one request group."""
    mu_input: float
    sigma_input: float
    mu_output: float
    sigma_output: float

    @staticmethod
    def fit(input_lens: Sequence[float], output_lens: Sequence[float]) -> "WorkloadProfile":
        import numpy as np
        i = np.asarray(input_lens, float)
        o = np.asarray(output_lens, float)
        return WorkloadProfile(float(i.mean()), float(i.std() + 1e-9),
                               float(o.mean()), float(o.std() + 1e-9))


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Per (model, device-type) constants from one profiling batch run."""
    # P: prefill seconds per 1k prompt tokens (the simulator and
    # prefill_seconds() charge it as a rate); used as-is as the constant
    # per-admission term when no prompt length is supplied (§6's "≈ constant
    # per model" reading, i.e. a ~1k-token prompt).
    prefill_time: float
    decode_per_token: float      # d seconds per decode iteration
    inefficiency: float          # ε ≥ 1, continuous-batching preemption factor
    token_capacity: int          # GPU — total KV tokens the device holds
    swap_time: float = 0.0       # S — model load time onto this device
    model_max_tokens: int = 2048  # decode bound for Eq. 4
    # Chunked-prefill quantum of the serving instance (None = single-shot
    # lump prefill).  With chunking, a prompt of I tokens occupies
    # ceil(I / chunk) iterations that each also run a decode step, so the
    # prefill term of C_q grows by that interleaving overhead.
    prefill_chunk_tokens: Optional[int] = None
    # Sliding-window width of the model served on this profile (None = full
    # attention).  The real engine clamps its chunk quantum to the window
    # (engine._chunk_quantum: a single chunk must never write the same
    # rolling cache slot twice); carrying the window here lets the
    # simulator and the RWT prefill term charge the SAME per-model chunk
    # counts instead of one approximate quantum per policy.
    sliding_window: Optional[int] = None
    # Fused multi-step decode width of the serving instance
    # (EngineConfig.decode_burst): the engine dispatches up to this many
    # decode iterations per host round-trip, so the per-dispatch host
    # overhead below amortizes across the burst instead of being charged
    # per token.
    decode_burst: int = 1
    # Host + dispatch seconds per fused decode dispatch (the
    # host_overhead_fraction engine_bench.py measures, in absolute terms).
    # 0 folds it into decode_per_token (the pre-burst reading).
    dispatch_overhead: float = 0.0

    def decode_seconds(self, burst: Optional[int] = None) -> float:
        """Effective seconds per decode ITERATION: pure per-token compute
        ``d`` plus the per-dispatch host overhead amortized over the burst
        width (``burst`` overrides ``self.decode_burst``; chunk-interleaved
        iterations run single-step, so they pass 1)."""
        b = max(burst if burst is not None else self.decode_burst, 1)
        return self.decode_per_token + self.dispatch_overhead / b

    def chunk_quantum(self, quantum: Optional[int] = None) -> Optional[int]:
        """Effective per-model chunked-prefill quantum (mirrors the
        engine's sliding-window clamp); None = lump prefill.

        ``quantum`` overrides ``self.prefill_chunk_tokens`` as the
        unclamped quantum (the simulator passes the policy's value so the
        clamp lives in ONE place).  ``sliding_window`` is expected to be
        pre-capped at the engine's max_seq_len by its producer
        (``calibrate_from_engine`` does this).
        """
        c = quantum if quantum is not None else self.prefill_chunk_tokens
        if c and self.sliding_window is not None:
            return min(c, self.sliding_window)
        return c

    def prefill_seconds(self, prompt_tokens: Optional[float] = None,
                        effective_prompt_tokens: Optional[float] = None) -> float:
        """Prefill term P for one request.

        Without ``prompt_tokens`` this is the paper's constant P.  With it,
        P scales per-1k-prompt-tokens (matching the simulator's accounting)
        and, when the instance prefills in chunks, adds one interleaved
        decode iteration per chunk (window-clamped via ``chunk_quantum``).

        ``effective_prompt_tokens`` is the portion that actually runs
        prefill compute once shared-prefix KV cache hits are subtracted
        (engine: chunked prefill starts at the first unshared token) — the
        rate AND the chunk count both scale with it, so waiting-time
        estimates reflect cache hits.  Defaults to ``prompt_tokens``
        (no sharing).  Chunk-interleaved decode iterations dispatch
        single-step, hence ``decode_seconds(burst=1)``.
        """
        if prompt_tokens is None:
            return self.prefill_time
        eff = effective_prompt_tokens if effective_prompt_tokens is not None \
            else prompt_tokens
        eff = min(max(eff, 0.0), prompt_tokens)
        t = self.prefill_time * (eff / 1024.0)
        chunk = self.chunk_quantum()
        if chunk:
            n_chunks = math.ceil(max(eff, 1.0) / chunk)
            t += n_chunks * self.decode_seconds(burst=1)
        return t

    def batch_size(self, wl: WorkloadProfile) -> float:
        """Eq. 16: B ≈ GPU / E[I + O]."""
        return self.token_capacity / max(wl.mu_input + wl.mu_output, 1.0)

    def throughput(self, wl: WorkloadProfile) -> float:
        """Eq. 15: Θ = B / (d · ε) output tokens per second, with d the
        burst-amortized per-iteration cost (``decode_seconds``)."""
        return self.batch_size(wl) / (self.decode_seconds() * self.inefficiency)


@dataclasses.dataclass(frozen=True)
class WaitEstimate:
    mean: float
    std: float

    def conservative(self, z: float = 1.0) -> float:
        return self.mean + z * self.std


class RWTEstimator:
    """Stateless estimator; all state arrives via the profiles."""

    def __init__(self, z_conservative: float = 1.0):
        self.z = z_conservative

    # -- Eq. 2/3: waiting time for a request at queue position q ----------
    def waiting_time(self, queue_position: int, wl: WorkloadProfile,
                     hw: HardwareProfile) -> WaitEstimate:
        q_ahead = max(queue_position, 0)
        theta = hw.throughput(wl)
        mean = q_ahead * wl.mu_output / theta
        std = math.sqrt(q_ahead) * wl.sigma_output / theta
        return WaitEstimate(mean, std)

    # -- Eq. 4: conservative decode bound ---------------------------------
    def decode_time(self, hw: HardwareProfile,
                    max_output_tokens: Optional[int] = None) -> float:
        o = max_output_tokens if max_output_tokens is not None else hw.model_max_tokens
        return o * hw.inefficiency * hw.decode_seconds()

    # -- Eq. 1/5: completion bound for a request / group ------------------
    def request_completion(self, queue_position: int, wl: WorkloadProfile,
                           hw: HardwareProfile,
                           max_output_tokens: Optional[int] = None,
                           prompt_tokens: Optional[float] = None,
                           effective_prompt_tokens: Optional[float] = None
                           ) -> WaitEstimate:
        """Eq. 1/5.  ``prompt_tokens`` (e.g. ``wl.mu_input``) switches the
        prefill term from the constant P to the token-scaled,
        chunk-interleaving-aware estimate (``hw.prefill_seconds``);
        ``effective_prompt_tokens`` further subtracts shared-prefix cache
        hits from the prefill work (engine skips prefill for cached full
        blocks)."""
        w = self.waiting_time(queue_position, wl, hw)
        extra = hw.prefill_seconds(prompt_tokens, effective_prompt_tokens) \
            + self.decode_time(hw, max_output_tokens)
        return WaitEstimate(w.mean + extra, w.std)

    def group_drain_time(self, n_requests: int, wl: WorkloadProfile,
                         hw: HardwareProfile,
                         prompt_tokens: Optional[float] = None,
                         effective_prompt_tokens: Optional[float] = None
                         ) -> WaitEstimate:
        """Eq. 5 over a whole request group: the LAST request's completion.

        The group's total output tokens ~ N(nμ_o, nσ_o²); drain = tokens/Θ,
        plus the conservative tail decode for the final request.
        ``prompt_tokens`` (the group's μ_input) makes the prefill term
        token-scaled and chunk-interleaving-aware (``hw.prefill_seconds``);
        ``effective_prompt_tokens`` (the group's μ_input net of expected
        prefix-cache hits — request groups share prompt templates, so the
        hit rate is per-group) shrinks it accordingly.
        """
        theta = hw.throughput(wl)
        mean = n_requests * wl.mu_output / theta
        std = math.sqrt(max(n_requests, 1)) * wl.sigma_output / theta
        return WaitEstimate(
            mean + hw.prefill_seconds(prompt_tokens, effective_prompt_tokens),
            std)

    def group_first_token_time(self, n_ahead_tokens: float,
                               wl: WorkloadProfile, hw: HardwareProfile,
                               prompt_tokens: Optional[float] = None,
                               effective_prompt_tokens: Optional[float] = None
                               ) -> float:
        """TTFT for a group whose predecessors hold ``n_ahead_tokens``
        pending output tokens (used by the violation monitor)."""
        theta = hw.throughput(wl)
        return n_ahead_tokens / theta \
            + hw.prefill_seconds(prompt_tokens, effective_prompt_tokens)

    # -- accuracy metric (Fig. 18) ----------------------------------------
    @staticmethod
    def r_squared(predicted: Sequence[float], actual: Sequence[float]) -> float:
        import numpy as np
        p = np.asarray(predicted, float)
        a = np.asarray(actual, float)
        ss_res = float(np.sum((a - p) ** 2))
        ss_tot = float(np.sum((a - a.mean()) ** 2))
        return 1.0 - ss_res / max(ss_tot, 1e-12)
