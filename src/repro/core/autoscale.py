"""Scale-up & admission control (paper §9 / §8.2 / Fig. 1-right).

When the global scheduler cannot find a feasible ordering the paper's
options are (a) scale up serving instances, (b) EDF fallback (implemented
in the scheduler), (c) admission control.  This module implements (a) and
(c):

* ``find_min_instances`` — the Fig. 1 (right) experiment: the smallest
  cluster that keeps SLO attainment above a target, per policy.  QLM's
  better multiplexing needs fewer devices than systems that split
  batch/interactive or per-model (the paper's 2-vs-4-GPU example).
* ``AdmissionController`` — drop/reject requests once the estimated queue
  drain exceeds a bound (§9 option (c)).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.request import Request
from repro.core.rwt_estimator import HardwareProfile, RWTEstimator, WorkloadProfile


def find_min_instances(run_with_n: Callable[[int], Dict[str, float]],
                       *, slo_target: float = 0.99,
                       lo: int = 1, hi: int = 16) -> Dict[str, object]:
    """Binary search the smallest instance count meeting ``slo_target``.

    ``run_with_n(n)`` runs the workload on an n-instance cluster and
    returns the metrics dict (ClusterSimulator.run).
    """
    results: Dict[int, float] = {}

    def ok(n: int) -> bool:
        if n not in results:
            results[n] = run_with_n(n)["slo_attainment"]
        return results[n] >= slo_target

    if not ok(hi):
        return {"min_instances": None, "attainment_by_n": results}
    while lo < hi:
        mid = (lo + hi) // 2
        if ok(mid):
            hi = mid
        else:
            lo = mid + 1
    return {"min_instances": hi, "attainment_by_n": results}


@dataclasses.dataclass
class AdmissionController:
    """§9(c): reject incoming requests when the RWT-estimated queue drain
    already exceeds ``max_drain_s`` (rate limiting keeps the queue bounded
    so admitted requests can still meet SLOs)."""
    estimator: RWTEstimator
    hw: HardwareProfile
    max_drain_s: float
    rejected: List[Request] = dataclasses.field(default_factory=list)

    def admit(self, req: Request, queue_pending_requests: int,
              wl: Optional[WorkloadProfile] = None) -> bool:
        wl = wl or WorkloadProfile(req.prompt_len, 1.0,
                                   float(req.max_new_tokens), 1.0)
        est = self.estimator.waiting_time(queue_pending_requests, wl, self.hw)
        if est.conservative(self.estimator.z) > self.max_drain_s:
            self.rejected.append(req)
            return False
        return True
