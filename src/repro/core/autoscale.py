"""Scale-up & admission control (paper §9 / §8.2 / Fig. 1-right).

When the global scheduler cannot find a feasible ordering the paper's
options are (a) scale up serving instances, (b) EDF fallback (implemented
in the scheduler), (c) admission control.  This module implements (a) and
(c):

* ``find_min_instances`` — the Fig. 1 (right) experiment: the smallest
  cluster that keeps SLO attainment above a target, per policy.  QLM's
  better multiplexing needs fewer devices than systems that split
  batch/interactive or per-model (the paper's 2-vs-4-GPU example).
* ``AdmissionController`` — drop/reject requests once the estimated queue
  drain exceeds a bound (§9 option (c)).
* ``ReplacementPolicy`` — the self-healing half of (a): replace departed
  (dead/drained) instances and scale out, driven by REAL cluster signals
  (lost-capacity fraction, RWT-estimated queue drain) instead of
  synthetic ones.  The actuator is
  ``QLMController.replace_instance``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.request import Request
from repro.core.rwt_estimator import HardwareProfile, RWTEstimator, WorkloadProfile


def find_min_instances(run_with_n: Callable[[int], Dict[str, float]],
                       *, slo_target: float = 0.99,
                       lo: int = 1, hi: int = 16) -> Dict[str, object]:
    """Binary search the smallest instance count meeting ``slo_target``.

    ``run_with_n(n)`` runs the workload on an n-instance cluster and
    returns the metrics dict (ClusterSimulator.run).
    """
    results: Dict[int, float] = {}

    def ok(n: int) -> bool:
        if n not in results:
            results[n] = run_with_n(n)["slo_attainment"]
        return results[n] >= slo_target

    if not ok(hi):
        return {"min_instances": None, "attainment_by_n": results}
    while lo < hi:
        mid = (lo + hi) // 2
        if ok(mid):
            hi = mid
        else:
            lo = mid + 1
    return {"min_instances": hi, "attainment_by_n": results}


@dataclasses.dataclass
class AdmissionController:
    """§9(c): reject incoming requests when the RWT-estimated queue drain
    already exceeds ``max_drain_s`` (rate limiting keeps the queue bounded
    so admitted requests can still meet SLOs).

    ``hw`` must be the CALIBRATED profile of the instances that can serve
    the request's model, and ``n_instances`` the number of schedulable
    such instances: the gate sees the cluster-wide queue depth, so
    dividing it by a single instance's throughput over-rejects by a
    factor of the cluster size (the PR 6 ``--admit-drain slo``
    over-rejection on small-model CPU setups)."""
    estimator: RWTEstimator
    hw: HardwareProfile
    max_drain_s: float
    n_instances: int = 1
    rejected: List[Request] = dataclasses.field(default_factory=list)

    def admit(self, req: Request, queue_pending_requests: int,
              wl: Optional[WorkloadProfile] = None) -> bool:
        wl = wl or WorkloadProfile(req.prompt_len, 1.0,
                                   float(req.max_new_tokens), 1.0)
        # load-balanced split: each serving instance drains its share of
        # the queue, so the per-instance depth is ceil(depth / n)
        n = max(1, self.n_instances)
        depth = -(-max(queue_pending_requests, 0) // n)
        est = self.estimator.waiting_time(depth, wl, self.hw)
        if est.conservative(self.estimator.z) > self.max_drain_s:
            self.rejected.append(req)
            return False
        return True


@dataclasses.dataclass
class ReplacementPolicy:
    """Replacement / scale-out trigger for the self-healing cluster
    (paper §9 option (a), recovery-driven).

    Reads two REAL signals off a ``QLMController``:

      * **dead capacity** — the fraction of attached instances that
        departed (DEAD or DRAINED).  Above ``max_departed_fraction`` the
        departed slots are due for replacement.
      * **queue drain** — a coarse RWT-style estimate of how long the
        surviving schedulable capacity needs to drain the queued
        backlog.  Above ``max_drain_s`` the cluster is due for
        replacement even if the departed fraction alone is tolerable
        (``scale_out_due`` exposes the same signal for net-new growth).

    The policy only *decides*; the caller builds the fresh engine and
    calls ``QLMController.replace_instance`` (engines are processes /
    devices — standing one up is the launcher's job, not the
    controller's).  ``cooldown_s`` rate-limits decisions so a slow
    engine bring-up is not re-triggered every tick."""
    max_departed_fraction: float = 0.0   # any departure is due by default
    max_drain_s: float = math.inf
    cooldown_s: float = 0.0
    _last_decision: float = dataclasses.field(default=-math.inf, repr=False)

    def departed(self, controller) -> List[int]:
        return [i for i in range(len(controller.instances))
                if not controller.is_alive(i)]

    def queue_drain_s(self, controller) -> float:
        """Estimated seconds the SCHEDULABLE survivors need to drain the
        queued (non-in-flight, non-terminal) backlog — infinite with no
        survivors and a non-empty backlog."""
        backlog = [r for r in controller.global_queue
                   if not r.finished() and not getattr(r, "_in_flight",
                                                       False)]
        if not backlog:
            return 0.0
        rate = 0.0
        for i, inst in enumerate(controller.instances):
            if not controller.is_schedulable(i):
                continue
            for hw in inst.hw_by_model.values():
                # requests/second this instance retires, crudely: one
                # prefill + the mean remaining decode work per request
                per_req = hw.prefill_time + hw.decode_per_token * max(
                    1.0, sum(r.max_new_tokens - r.generated
                             for r in backlog) / len(backlog))
                rate += 1.0 / max(per_req, 1e-9)
                break   # one profile per instance is enough for a bound
        if rate <= 0.0:
            return math.inf
        return len(backlog) / rate

    def replacements_due(self, controller, now: float) -> List[int]:
        """Instance indices whose departed capacity should be replaced
        now ([] inside the cooldown or while the signals are green)."""
        if now - self._last_decision < self.cooldown_s:
            return []
        n = len(controller.instances)
        gone = self.departed(controller)
        if not n or not gone:
            return []
        if (len(gone) / n) > self.max_departed_fraction \
                or self.queue_drain_s(controller) > self.max_drain_s:
            self._last_decision = now
            return gone
        return []

    def scale_out_due(self, controller, now: float) -> bool:
        """True when the backlog alone (all instances healthy) warrants
        net-new capacity — the §9(a) scale-UP signal."""
        if now - self._last_decision < self.cooldown_s:
            return False
        if self.queue_drain_s(controller) > self.max_drain_s:
            self._last_decision = now
            return True
        return False
