"""Virtual queues (paper §4, Def. 4.2).

A virtual queue is an ordered sequence of request-group references with a
one-to-one mapping to an LLM serving instance.  Requests themselves stay in
the global queue (single replica — fault-tolerance §4); the VQ holds
*pointers*, so it can be rebuilt or reassigned without touching request
data (fault isolation / consistency argument of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.request import Request
from repro.core.request_group import RequestGroup


@dataclasses.dataclass
class VirtualQueue:
    instance_id: int
    groups: List[RequestGroup] = dataclasses.field(default_factory=list)

    def head_group(self) -> Optional[RequestGroup]:
        while self.groups and self.groups[0].done():
            self.groups.pop(0)  # dequeue completed groups (§4)
        return self.groups[0] if self.groups else None

    def set_order(self, groups: List[RequestGroup]) -> None:
        self.groups = [g for g in groups if not g.done()]

    def next_request(self, model: Optional[str] = None,
                     now: Optional[float] = None) -> Optional[Request]:
        """§5 Request Pulling: FCFS within the head group; when every head
        request is already in flight, pulling continues into subsequent
        groups (continuous batching keeps the device fed) — but stops at the
        first group whose model differs from the loaded one (``model``),
        since serving it requires a swap decision by the global scheduler.

        ``now`` gates redelivered requests still in exponential backoff
        (``Request.not_before``): they are skipped, not dropped, so the
        pull continues past them and the slot goes to servable work.
        """
        self.head_group()  # drop completed head groups
        for g in self.groups:
            if g.done():
                continue
            if model is not None and g.model != model:
                return None  # swap boundary
            r = g.next_pending(now=now)  # arrival-ordered (FCFS inside group)
            if r is not None:
                return r
        return None

    def pending_requests(self) -> int:
        return sum(g.num_pending() for g in self.groups)

    def models_in_order(self) -> List[str]:
        return [g.model for g in self.groups if not g.done()]

    def __len__(self) -> int:
        return len([g for g in self.groups if not g.done()])
