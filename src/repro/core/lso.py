"""QLM agent: translates virtual-queue order into LSO actions (paper §5).

One agent per LLM serving instance.  The agent is a pure actuator — all
intelligence lives in the global scheduler's VQ ordering:

  * Request pulling  — engine.pull_source bound to the VQ head group (FCFS
    within the group);
  * Request eviction — when the head group changes, running requests from
    other groups are evicted (KV snapshotted to host) to un-block HOL;
  * Model swapping   — when the head group's model differs from the loaded
    one, flush + swap;
  * Load balancing   — implicit: each instance only pulls from its own VQ.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, Optional, Tuple

from repro.core.request import Request
from repro.core.virtual_queue import VirtualQueue
from repro.serving.engine import ContinuousBatchingEngine


class QLMAgent:
    def __init__(self, engine: ContinuousBatchingEngine, vq: VirtualQueue,
                 model_registry: Dict[str, Tuple[object, object]],
                 *, enable_eviction: bool = True, enable_swap: bool = True):
        """model_registry: name -> (Model, params)."""
        self.engine = engine
        self.vq = vq
        self.registry = model_registry
        self.enable_eviction = enable_eviction
        self.enable_swap = enable_swap
        self._last_head = None  # eviction fires on head-group CHANGE (§5)
        # Queue-layer guard for threaded serving: the cluster runtime
        # binds this to ``QLMController.lock`` so ``_pull`` (fired
        # mid-round via ``engine.pull_source``) and ``sync`` serialize
        # against ticks / submits / mark_dead.  Lock order is
        # engine.lock -> queue_lock (run_iteration holds the engine lock
        # around the whole quantum); the controller side never blocks on
        # engine locks, so the cross order cannot deadlock.  Default is
        # a no-op for single-threaded drivers.
        self.queue_lock: contextlib.AbstractContextManager = \
            contextlib.nullcontext()
        engine.pull_source = self._pull

    # -- request pulling LSO ------------------------------------------------
    def _pull(self) -> Optional[Request]:
        with self.queue_lock:
            pushed = self.engine.take_pushback()
            if pushed is not None:
                pushed._in_flight = False
                pushed._served_by = None
            # clock-gated: redelivered requests in exponential backoff
            # (not_before) are skipped until their window opens
            req = self.vq.next_request(self.engine.model_name,
                                       now=self.engine.clock())
            if req is None:
                return None
            req._in_flight = True
            # tag the serving instance: on engine death the supervisor
            # sweeps the global queue for _served_by == this VQ's instance
            req._served_by = self.vq.instance_id
            return req

    # -- eviction + swap LSOs -------------------------------------------------
    def sync(self) -> None:
        """Reconcile engine state with the (possibly re-ordered) VQ."""
        with self.queue_lock:
            self._sync_locked()

    def _sync_locked(self) -> None:
        head = self.vq.head_group()
        if head is None:
            return
        # model swapping: head group's model must be resident
        if self.enable_swap and head.model != self.engine.model_name:
            model, params = self.registry[head.model]
            evicted = self.engine.swap_model(model, params, head.model)
            for r in evicted:
                r._in_flight = False
                r._served_by = None
            # the swap rebuilt engine state: forget the cached head so the
            # head-change eviction LSO re-evaluates on the next sync
            self._last_head = None
        # request eviction: fires when the global scheduler moved a NEW
        # group to the head (§5) and its requests are blocked by other
        # groups' running requests (HOL un-blocking)
        head_changed = head.group_id != self._last_head
        self._last_head = head.group_id
        if self.enable_eviction and head_changed:
            head_pending = [r for r in head.pending()
                            if not getattr(r, "_in_flight", False)]
            if head_pending and not any(
                    self.engine.can_admit(r) for r in head_pending):
                for slot in list(self.engine.active_slots()):
                    running = self.engine.slots[slot]
                    if running is not None and running.group_id != head.group_id:
                        r = self.engine.evict_slot(slot)
                        r._in_flight = False
                        r._served_by = None
                        if self.engine.can_admit(head_pending[0]):
                            break

    def reset(self) -> None:
        """Failure-path reset (engine crash / recovery / external engine
        reset): forget the cached VQ head — the first post-recovery
        ``sync()`` must re-evaluate the head-change eviction LSO instead
        of assuming continuity with pre-failure state — and drain any
        pushback limbo so no request strands with ``_in_flight=True``."""
        self._last_head = None
        with self.queue_lock:
            pushed = self.engine.take_pushback()
            if pushed is not None:
                pushed._in_flight = False
                pushed._served_by = None

    def run_iteration(self):
        """sync + one engine iteration (the serve loop quantum).  Engines
        configured with ``decode_burst > 1`` fuse up to that many decode
        iterations into the dispatch (``steps()`` falls back to ``step()``
        at burst 1, and to single-step whenever a slot is mid-prefill)).

        The whole quantum runs under the engine's round lock: the
        controller's cross-thread LSO touches (migration materialize,
        drain eviction, dead-engine salvage) are excluded from the
        middle of a dispatch, and because those sites only try-lock,
        holding it for the full quantum is deadlock-free."""
        lock = getattr(self.engine, "lock", None)
        with lock if lock is not None else contextlib.nullcontext():
            self.sync()
            return self.engine.steps()
