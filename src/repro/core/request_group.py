"""Request groups (paper §4, Algorithm 1).

Groups are formed by (i) partitioning on model type (Def. 4.1 — groups are
homogeneous in model so swap decisions are group-level), (ii) k-means
clustering on the numeric features (SLO value, prompt length, expected
output length), then (iii) splitting any group larger than
``avg_batch_size × δ`` in half (Algorithm 1).  Requests inside a group are
FCFS (§4).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request
from repro.core.rwt_estimator import WorkloadProfile

_group_counter = itertools.count()


@dataclasses.dataclass
class RequestGroup:
    model: str
    slo: float                       # min SLO across members (conservative)
    requests: List[Request] = dataclasses.field(default_factory=list)
    group_id: int = dataclasses.field(default_factory=lambda: next(_group_counter))

    def add(self, req: Request) -> None:
        req.group_id = self.group_id
        self.requests.append(req)
        self.slo = min(self.slo, req.slo)
        self._wl_cache = None

    def size(self) -> int:
        return len(self.requests)

    # FCFS cursor: requests before ``_cursor`` are all finished.  Keeps
    # done()/next_pending() amortized O(running-batch) instead of O(group).
    def _advance(self) -> int:
        c = getattr(self, "_cursor", 0)
        reqs = self.requests
        while c < len(reqs) and reqs[c].finished():
            c += 1
        self._cursor = c
        return c

    def pending(self) -> List[Request]:
        c = self._advance()
        return [r for r in self.requests[c:] if not r.finished()]

    def num_pending(self) -> int:
        c = self._advance()
        n = 0
        for r in self.requests[c:]:
            if not r.finished():
                n += 1
        return n

    def next_pending(self, *, skip_in_flight: bool = True,
                     now: Optional[float] = None) -> Optional[Request]:
        """FCFS head of the group's waiting requests.  ``now`` enables the
        redelivery backoff gate: a request returned to the queue by an
        engine failure carries ``not_before`` and is skipped (not popped —
        FCFS order is preserved) until its backoff expires."""
        c = self._advance()
        for r in self.requests[c:]:
            if r.finished():
                continue
            if skip_in_flight and getattr(r, "_in_flight", False):
                continue
            if now is not None and getattr(r, "not_before", 0.0) > now:
                continue
            return r
        return None

    def done(self) -> bool:
        return self._advance() >= len(self.requests)

    def earliest_deadline(self) -> float:
        pend = self.pending()
        if not pend:
            return math.inf
        return min(r.deadline for r in pend)

    def workload_profile(self, expected_output: Optional[float] = None) -> WorkloadProfile:
        if expected_output is None and getattr(self, "_wl_cache", None) is not None:
            return self._wl_cache
        ins = [r.prompt_len for r in self.requests] or [1.0]
        outs = [r.max_new_tokens for r in self.requests] or [1.0]
        if expected_output is not None:
            outs = [expected_output] * len(self.requests)
        wl = WorkloadProfile.fit(ins, outs)
        if expected_output is None:
            self._wl_cache = wl
        return wl

    def total_expected_output_tokens(self, mu_output: Optional[float] = None) -> float:
        pend = self.pending()
        if mu_output is None:
            return float(sum(r.max_new_tokens - r.generated for r in pend))
        return mu_output * len(pend)


def _kmeans(features: np.ndarray, k: int, iters: int = 20,
            seed: int = 0) -> np.ndarray:
    """Tiny Lloyd's k-means (numpy only). Returns labels (n,)."""
    n = len(features)
    k = max(1, min(k, n))
    rng = np.random.default_rng(seed)
    # k-means++ style init: spread starting centers
    centers = features[rng.choice(n, size=1)]
    while len(centers) < k:
        d2 = np.min(((features[:, None, :] - centers[None]) ** 2).sum(-1), axis=1)
        probs = d2 / max(d2.sum(), 1e-12)
        centers = np.vstack([centers, features[rng.choice(n, p=probs)]])
    labels = np.zeros(n, int)
    for _ in range(iters):
        d2 = ((features[:, None, :] - centers[None]) ** 2).sum(-1)
        new_labels = d2.argmin(1)
        if (new_labels == labels).all():
            break
        labels = new_labels
        for j in range(k):
            m = labels == j
            if m.any():
                centers[j] = features[m].mean(0)
    return labels


def create_request_groups(requests: Sequence[Request], *,
                          avg_batch_size: float = 32.0,
                          delta: float = 4.0,
                          clusters_per_model: Optional[int] = None,
                          seed: int = 0) -> List[RequestGroup]:
    """Algorithm 1: cluster, then split oversized groups."""
    max_group = max(1, int(avg_batch_size * delta))
    by_model: Dict[str, List[Request]] = defaultdict(list)
    for r in requests:
        by_model[r.model].append(r)

    groups: List[RequestGroup] = []
    for model, reqs in by_model.items():
        feats = np.array([[math.log(r.slo), r.prompt_len, r.max_new_tokens]
                          for r in reqs], float)
        # normalize features
        feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-9)
        k = clusters_per_model
        if k is None:
            n_slo = len({r.slo_class or r.slo for r in reqs})
            k = max(n_slo, int(math.ceil(len(reqs) / max_group)))
        labels = _kmeans(feats, k, seed=seed)
        for j in sorted(set(labels)):
            members = [reqs[i] for i in np.flatnonzero(labels == j)]
            members.sort(key=lambda r: r.arrival_time)  # FCFS inside group
            g = RequestGroup(model=model, slo=min(r.slo for r in members))
            for r in members:
                g.add(r)
            groups.append(g)

    # Algorithm 1 lines 2–7: split while size > avg_batch_size × δ
    out: List[RequestGroup] = []
    work = list(groups)
    while work:
        g = work.pop()
        if g.size() > max_group:
            half = g.size() // 2
            g1 = RequestGroup(model=g.model, slo=g.slo)
            g2 = RequestGroup(model=g.model, slo=g.slo)
            for r in g.requests[:half]:
                g1.add(r)
            for r in g.requests[half:]:
                g2.add(r)
            work.extend([g1, g2])
        else:
            out.append(g)
    out.sort(key=lambda g: g.earliest_deadline())
    return out


def classify_into_groups(req: Request, groups: List[RequestGroup], *,
                         max_group: int,
                         slo_band: float = 2.0) -> Optional[RequestGroup]:
    """§4 "Handling New Incoming Requests": attach to the nearest existing
    compatible group with capacity, else signal that a new group is needed.

    Only groups that still have WAITING members are attach targets: when the
    system is underloaded every group is fully in-flight, so new arrivals
    form fresh groups and get least-loaded placement (QLM == FCFS at queue
    size 0, Fig. 17's left edge); amortization via large groups only kicks
    in when a real queue exists.

    ``slo_band`` bounds the SLO ratio between the request and the group it
    may join (Algorithm 1 clusters ON the SLO feature; the incremental
    attach path must respect the same partition).  A group's SLO is the min
    over members, so without the band one interactive arrival attached to a
    batch group re-deadlines the WHOLE group as interactive: the RWT walk
    then sees hours of batch decode behind an interactive deadline
    (violation storms), and any SLO-class queue policy — e.g. the front
    end's interactive-first ordering — can no longer separate the classes.
    """
    candidates = [g for g in groups
                  if g.model == req.model and g.size() < max_group
                  and max(g.slo, req.slo) <= slo_band * min(g.slo, req.slo)
                  and not g.done() and g.next_pending() is not None]
    if not candidates:
        return None
    def dist(g: RequestGroup) -> float:
        wl = g.workload_profile()
        return (abs(math.log(max(g.slo, 1e-9)) - math.log(max(req.slo, 1e-9)))
                + abs(wl.mu_input - req.prompt_len) / max(wl.mu_input, 1.0))
    best = min(candidates, key=dist)
    best.add(req)
    return best
