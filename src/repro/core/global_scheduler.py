"""Global scheduler (paper §7): RWT-triggered virtual-queue reordering.

Invoked when the RWT estimator predicts an SLO violation; builds the MILP
(``core.solver``) from current request groups + per-instance hardware
profiles (heterogeneity enters via each instance's HardwareProfile — §3.2
Design Principle #3) and rewrites every virtual queue's group order.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile, RWTEstimator
from repro.core.solver import GroupSpec, InstanceSpec, Solution, solve
from repro.core.virtual_queue import VirtualQueue


@dataclasses.dataclass
class InstanceInfo:
    """Scheduler view of one LLM serving instance."""
    instance_id: int
    hw_by_model: Dict[str, HardwareProfile]  # per-model profile on THIS device
    current_model: Optional[str]
    virtual_queue: VirtualQueue

    def hw(self, model: str) -> HardwareProfile:
        return self.hw_by_model[model]

    def swap_times(self) -> Dict[str, float]:
        return {m: hw.swap_time for m, hw in self.hw_by_model.items()}


class GlobalScheduler:
    def __init__(self, estimator: Optional[RWTEstimator] = None, seed: int = 0,
                 exact_threshold: int = 0, objective: str = "penalty"):
        self.estimator = estimator or RWTEstimator()
        self.seed = seed
        self.exact_threshold = exact_threshold
        self.objective = objective
        self.invocations = 0

    # ------------------------------------------------------------------
    def build_specs(self, groups: Sequence[RequestGroup],
                    instances: Sequence[InstanceInfo], now: float):
        gspecs: List[GroupSpec] = []
        for g in groups:
            wl = g.workload_profile()
            drain = {}
            for inst in instances:
                if g.model not in inst.hw_by_model:
                    drain[inst.instance_id] = math.inf  # can't serve here
                    continue
                est = self.estimator.group_drain_time(
                    len(g.pending()), wl, inst.hw(g.model),
                    prompt_tokens=wl.mu_input)
                drain[inst.instance_id] = est.conservative(self.estimator.z)
            gspecs.append(GroupSpec(
                group_id=g.group_id, model=g.model,
                slo=max(g.earliest_deadline() - now, 0.0), drain_time=drain,
                size=float(len(g.pending()))))
        ispecs = [InstanceSpec(inst.instance_id, inst.current_model,
                               inst.swap_times()) for inst in instances]
        return gspecs, ispecs

    def schedule(self, groups: Sequence[RequestGroup],
                 instances: Sequence[InstanceInfo], now: float) -> Solution:
        """Solve and APPLY the new virtual-queue orders.

        If Eq. 12 is infeasible (demand > capacity) the paper §9(b) falls
        back to EDF and keeps serving (option (a), scale-up, needs new
        hardware; option (c), admission control, drops requests).  The
        solver's min-total-penalty order can sacrifice many small deadlines
        for one large group, so EDF is the better attainment heuristic in
        that regime — we compare both and keep the EDF fallback's behavior
        whenever the solve is infeasible.
        """
        self.invocations += 1
        live = [g for g in groups if not g.done()]
        gspecs, ispecs = self.build_specs(live, instances, now)
        sol = solve(gspecs, ispecs, exact_threshold=self.exact_threshold,
                    seed=self.seed + self.invocations,
                    objective=self.objective)
        if not sol.feasible:
            self._edf_fallback(live, instances)
            return sol
        by_idx = {i: g for i, g in enumerate(live)}
        for qi, inst in enumerate(instances):
            inst.virtual_queue.set_order([by_idx[gi] for gi in sol.assignment[qi]])
        return sol

    @staticmethod
    def _edf_fallback(groups: Sequence[RequestGroup],
                      instances: Sequence[InstanceInfo]) -> None:
        """§9(b): EDF over groups with model-affinity tiebreak (deadline
        first; groups of the instance's resident model keep their place)."""
        for inst in instances:
            inst.virtual_queue.set_order([])
        for g in sorted(groups, key=lambda g: g.earliest_deadline()):
            candidates = [i for i in instances if g.model in i.hw_by_model]
            if not candidates:
                # no surviving instance serves this model (capacity loss):
                # leave the group unplaced — the controller quarantines
                # unservable requests before re-solving, so reaching here
                # means the stranded-group invariant will name it
                continue
            inst = min(candidates,
                       key=lambda i: (0 if (i.virtual_queue.models_in_order() or
                                            [i.current_model])[-1] == g.model else 1,
                                      i.virtual_queue.pending_requests()))
            inst.virtual_queue.groups.append(g)

    # ------------------------------------------------------------------
    def predict_violation(self, instances: Sequence[InstanceInfo],
                          now: float) -> bool:
        """Walk each VQ accumulating RWT drain estimates; violation iff some
        group's predicted completion exceeds its deadline slack (§4
        "Handling New Incoming Requests")."""
        return bool(self.violations(instances, now))

    def violations(self, instances: Sequence[InstanceInfo], now: float,
                   slo_ceiling: Optional[float] = None,
                   inflight: Optional[Sequence[float]] = None
                   ) -> List[InstanceInfo]:
        """The instances whose VQ walk predicts a deadline violation.

        A queued group whose model is missing from this instance's
        ``hw_by_model`` is SKIPPED from the estimate rather than reported
        as a violation: re-solving cannot improve a persistent
        model/instance mismatch, so flagging it forever would make the
        controller re-solve every cooldown tick with no possible
        improvement (``QLMController.submit`` raises once, at submit time,
        when no instance at all can serve the model).

        ``slo_ceiling`` restricts which groups' deadlines COUNT as
        violations (e.g. ``SLO_INTERACTIVE`` → only interactive-class
        groups trigger) — every servable group still contributes its drain
        time to the walk, since batch work ahead of an interactive group
        is exactly what delays it.  The overload shedder uses this to act
        only when *interactive* traffic is at risk.

        ``inflight`` (seconds per instance, aligned with ``instances``)
        seeds each walk with the drain time of work already RESIDENT in
        that instance's engine slots.  The VQ alone under-predicts: a
        queued interactive group behind an empty VQ still waits for a
        running batch decode to free a slot.
        """
        out: List[InstanceInfo] = []
        for idx, inst in enumerate(instances):
            t = float(inflight[idx]) if inflight is not None else 0.0
            cur = inst.current_model
            for g in inst.virtual_queue.groups:
                if g.done():
                    continue
                if g.model not in inst.hw_by_model:
                    continue  # unservable here: no estimate possible
                hw = inst.hw(g.model)
                if g.model != cur:
                    t += hw.swap_time
                    cur = g.model
                wl = g.workload_profile()
                est = self.estimator.group_drain_time(
                    len(g.pending()), wl, hw, prompt_tokens=wl.mu_input)
                t += est.conservative(self.estimator.z)
                if now + t > g.earliest_deadline() \
                        and (slo_ceiling is None or g.slo <= slo_ceiling):
                    out.append(inst)
                    break
        return out
