"""Strict request priorities (paper §9 "How can QLM handle request
priorities?").

In the strict-priority model, every request of priority p executes before
any request of priority p+1; WITHIN a priority level the virtual-queue /
request-group / RWT machinery still optimizes SLO attainment.  Implemented
as a level-by-level solve: each priority level is scheduled onto queue
TAILS left by the levels above it.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence

from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.request_group import RequestGroup
from repro.core.solver import GroupSpec, InstanceSpec, solve


class PriorityScheduler(GlobalScheduler):
    """Groups carry the MIN priority of their members (requests are grouped
    within a priority level by the controller)."""

    @staticmethod
    def group_priority(g: RequestGroup) -> int:
        return min((getattr(r, "priority", 0) for r in g.requests), default=0)

    def schedule(self, groups: Sequence[RequestGroup],
                 instances: Sequence[InstanceInfo], now: float):
        self.invocations += 1
        live = [g for g in groups if not g.done()]
        by_level: Dict[int, List[RequestGroup]] = defaultdict(list)
        for g in live:
            by_level[self.group_priority(g)].append(g)

        # accumulate orders level by level (higher priority = lower number)
        orders: List[List[RequestGroup]] = [[] for _ in instances]
        tail_model = [inst.current_model for inst in instances]
        last_sol = None
        for level in sorted(by_level):
            lg = by_level[level]
            gspecs, _ = self.build_specs(lg, instances, now)
            ispecs = [InstanceSpec(inst.instance_id, tail_model[qi],
                                   inst.swap_times())
                      for qi, inst in enumerate(instances)]
            sol = solve(gspecs, ispecs, exact_threshold=self.exact_threshold,
                        seed=self.seed + self.invocations,
                        objective=self.objective)
            last_sol = sol
            for qi in range(len(instances)):
                for gi in sol.assignment[qi]:
                    orders[qi].append(lg[gi])
                if sol.assignment[qi]:
                    tail_model[qi] = lg[sol.assignment[qi][-1]].model
        for qi, inst in enumerate(instances):
            inst.virtual_queue.set_order(orders[qi])
        return last_sol
