"""Queue-ordering policies: QLM plus the paper's §8 baselines.

  * ``fcfs``      — vanilla vLLM scheduler (arrival order, no reordering);
  * ``edf``       — Earliest Deadline First over request groups;
  * ``shepherd``  — SHEPHERD-style: deadline-ordered ILP placement with
                    FIXED batches and deterministic worst-case execution
                    estimates (the over-estimation of Fig. 1); realized in
                    the simulator via ``fixed_batch`` execution semantics;
  * ``qlm``       — the full global scheduler (RWT + MILP + LSOs).

Each policy is an ``order(groups, instances, now) -> None`` that rewrites
the virtual queues in place; execution-semantics flags live in
``PolicyTraits``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.request_group import RequestGroup


@dataclasses.dataclass(frozen=True)
class PolicyTraits:
    name: str
    reorders: bool            # may reorder the queue
    uses_eviction: bool       # eviction LSO enabled
    plans_swaps: bool         # model-swap-aware placement
    continuous_batching: bool  # False => SHEPHERD-style fixed batches
    waiting_overestimate: float = 1.0  # multiplicative waiting-time bias
    # (SHEPHERD/Clockwork assume deterministic worst-case exec times: the
    #  paper's Fig. 1 shows they OVER-estimate LLM queue waiting time.)
    # Chunked-prefill quantum (tokens per sequence per iteration), matching
    # the real engine's EngineConfig.prefill_chunk_tokens: prefill cost is
    # spread over iterations that keep decoding, instead of one lump
    # iteration per admission round.  None => legacy lump accounting.
    # The engine additionally clamps its quantum to a model's sliding
    # window (engine._chunk_quantum); HardwareProfile.sliding_window
    # carries the window per (model, device) so the simulator and RWT
    # charge the SAME per-model chunk counts (hw.chunk_quantum()).
    prefill_chunk_tokens: Optional[int] = None


def _least_loaded(instances: Sequence[InstanceInfo]) -> InstanceInfo:
    return min(instances, key=lambda i: i.virtual_queue.pending_requests())


def _spread(groups: List[RequestGroup], instances: Sequence[InstanceInfo],
            keyfn: Callable[[RequestGroup], float]) -> None:
    """Distribute groups over instances; each queue ordered by keyfn."""
    for inst in instances:
        inst.virtual_queue.set_order([])
    for g in sorted(groups, key=keyfn):
        inst = _least_loaded(instances)
        inst.virtual_queue.groups.append(g)


class FCFSPolicy:
    traits = PolicyTraits("vllm", reorders=False, uses_eviction=False,
                          plans_swaps=False, continuous_batching=True)

    def order(self, groups, instances, now):
        live = [g for g in groups if not g.done()]
        _spread(live, instances,
                lambda g: min((r.arrival_time for r in g.pending()), default=math.inf))


class EDFPolicy:
    traits = PolicyTraits("edf", reorders=True, uses_eviction=False,
                          plans_swaps=False, continuous_batching=True)

    def order(self, groups, instances, now):
        live = [g for g in groups if not g.done()]
        _spread(live, instances, lambda g: g.earliest_deadline())


class ShepherdPolicy:
    """Deadline-ordered placement with fixed batching + the conservative
    deterministic waiting estimate (no RWT): over-provisions per Fig. 1."""
    traits = PolicyTraits("shepherd", reorders=True, uses_eviction=False,
                          plans_swaps=False, continuous_batching=False,
                          waiting_overestimate=1.6)

    def order(self, groups, instances, now):
        live = [g for g in groups if not g.done()]
        # SHEPHERD avoids multiplexing models on an instance (§1): bucket
        # groups by model and pin each model to a disjoint instance subset.
        models = sorted({g.model for g in live})
        for inst in instances:
            inst.virtual_queue.set_order([])
        if not live:
            return
        n_inst = len(instances)
        per_model: Dict[str, List[InstanceInfo]] = {}
        for i, m in enumerate(models):
            lo = (i * n_inst) // len(models)  # qlint: disable=unguarded-div -- live is non-empty here (guarded above), so models has >= 1 entry
            hi = max(lo + 1, ((i + 1) * n_inst) // len(models))  # qlint: disable=unguarded-div -- same: models derived from non-empty live
            per_model[m] = list(instances)[lo:hi]
        for g in sorted(live, key=lambda g: g.earliest_deadline()):
            subset = per_model[g.model]
            inst = min(subset, key=lambda i: i.virtual_queue.pending_requests())
            inst.virtual_queue.groups.append(g)


class QLMPolicy:
    traits = PolicyTraits("qlm", reorders=True, uses_eviction=True,
                          plans_swaps=True, continuous_batching=True)

    def __init__(self, scheduler: Optional[GlobalScheduler] = None):
        self.scheduler = scheduler or GlobalScheduler()

    def order(self, groups, instances, now):
        self.scheduler.schedule(groups, instances, now)


POLICIES = {
    "vllm": FCFSPolicy,
    "edf": EDFPolicy,
    "shepherd": ShepherdPolicy,
    "qlm": QLMPolicy,
}


def make_policy(name: str):
    return POLICIES[name]()
