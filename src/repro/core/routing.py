"""Slice-level load-balancing routing (PAPERS.md: "Slice-Level
Scheduling for High-Throughput and Load-Balanced LLM Serving").

The MILP solver places whole REQUEST GROUPS — its placement granularity
is the group, so one oversized group (up to ``avg_batch_size * delta``
requests) lands on one instance no matter how idle its siblings are, and
on a heterogeneous cluster the slow engine can inherit a monolith the
fast engines can't help with.  Slice-level routing re-partitions the
queue into SLICES of at most ``slice_size`` requests (one engine batch
quantum by default) and places each slice independently by estimated
earliest finish, so a hot group spreads across instances proportionally
to their calibrated speed.

The policy plugs in below the controller: ``QLMConfig.routing =
"slice"`` makes ``QLMController.reschedule`` call ``slice_schedule``
instead of ``GlobalScheduler.schedule``.  Everything downstream (VQ
pulls, LSO sync, invariants) is unchanged — slices ARE request groups,
so the single-placement / single-ownership invariants hold by
construction.

Head-to-head comparison against the solver placement:
``launch/serve.py --routing slice|solver`` (and ``--compare-routing``)
reports attainment and the per-instance estimated makespans
(``estimated_makespans`` here, ``per_instance_makespan`` in
``core/solver.py`` for a solver ``Solution``).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

from repro.core.request_group import RequestGroup

ROUTING_POLICIES = ("solver", "slice")


def slice_groups(groups: Sequence[RequestGroup],
                 slice_size: int) -> List[RequestGroup]:
    """Re-partition oversized groups into FCFS-contiguous slices of at
    most ``slice_size`` members.  Groups already within the quantum are
    kept BY IDENTITY (no group-id churn: the agents' head-change
    eviction LSO fires on id change, so stable groups must keep stable
    ids).  Members move wholesale — in-flight and finished members ride
    along with their slice (pull paths skip both; cursors are
    per-group and fresh slices start at zero)."""
    out: List[RequestGroup] = []
    for g in groups:
        if g.done():
            continue
        if g.size() <= slice_size:
            out.append(g)
            continue
        members = list(g.requests)
        for lo in range(0, len(members), slice_size):
            chunk = members[lo:lo + slice_size]
            s = RequestGroup(model=g.model,
                             slo=min(r.slo for r in chunk))
            for r in chunk:
                s.add(r)
            out.append(s)
    return out


def estimated_makespans(instances: Sequence, estimator, *,
                        now: float = 0.0,
                        z: Optional[float] = None) -> List[float]:
    """Per-instance RWT-estimated drain of the CURRENT virtual-queue
    orders (swap-aware walk, conservative bound) — the load-balance
    metric the routing comparison reports: a flat vector means the
    placement matched work to capacity."""
    z = estimator.z if z is None else z
    out: List[float] = []
    for inst in instances:
        t = 0.0
        cur = inst.current_model
        for g in inst.virtual_queue.groups:
            if g.done() or g.model not in inst.hw_by_model:
                continue
            hw = inst.hw(g.model)
            if g.model != cur:
                t += hw.swap_time
                cur = g.model
            wl = g.workload_profile()
            est = estimator.group_drain_time(len(g.pending()), wl, hw,
                                             prompt_tokens=wl.mu_input)
            t += est.conservative(z)
        out.append(t)
    return out


def slice_schedule(controller, now: float) -> List[RequestGroup]:
    """Slice the live groups and place every slice by estimated earliest
    finish (EDF consideration order, swap-aware, heterogeneity-aware via
    each instance's calibrated per-model profile).  Applies the new VQ
    orders on the SCHEDULABLE instances and replaces
    ``controller.groups`` with the slice partition.  Returns the placed
    slices.  Must run under the controller lock (``reschedule`` holds
    it)."""
    cfg = controller.cfg
    slice_size = cfg.slice_size or max(1, int(cfg.avg_batch_size))
    slices = slice_groups(controller.groups, slice_size)
    controller.groups = slices
    instances = controller.schedulable_instances()
    if not instances:
        return slices
    estimator = controller.estimator

    orders: List[List[RequestGroup]] = [[] for _ in instances]
    tails = [(0.0, inst.current_model) for inst in instances]
    # EDF consideration order: urgent slices grab the fast tails first
    for s in sorted(slices, key=lambda g: g.earliest_deadline()):
        best_qi, best_finish = None, math.inf
        wl = s.workload_profile()
        for qi, inst in enumerate(instances):
            if s.model not in inst.hw_by_model:
                continue
            t, cur = tails[qi]
            hw = inst.hw(s.model)
            dt = hw.swap_time if s.model != cur else 0.0
            est = estimator.group_drain_time(len(s.pending()), wl, hw,
                                             prompt_tokens=wl.mu_input)
            finish = t + dt + est.conservative(estimator.z)
            if finish < best_finish:
                best_qi, best_finish = qi, finish
        if best_qi is None:
            # no schedulable instance serves this model: leave the slice
            # unplaced — the controller quarantines unservable work
            # before re-placing, so reaching here is transient
            continue
        orders[best_qi].append(s)
        tails[best_qi] = (best_finish, s.model)
    for qi, inst in enumerate(instances):
        inst.virtual_queue.set_order(orders[qi])
    return slices
