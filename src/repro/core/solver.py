"""Global-scheduler optimization (paper §7, Table 2, Eqs. 6–13).

The paper formulates an ILP over binary x_{g,i,j} (group i → virtual queue
g, position j) with big-M linearized model-switch indicators t_{g,j}
(Eq. 9), cumulative waiting times wt_{g,j} that accumulate predecessor
completion times and swap times (Eq. 10), penalties p = wt − slo (Eq. 11),
the feasibility constraint p ≤ 0 (Eq. 12), and objective min Σ p (Eq. 13).

No external MILP solver is available offline, so this module implements the
same formulation directly over the *assignment representation* (each
feasible x is exactly a partition of groups into ordered queues — Eq. 6's
double stochasticity):

  * ``evaluate``          — the Eq. 10/11/13 objective for an assignment;
  * ``branch_and_bound``  — exact for small instances (prunes on the
                            monotone violation lower bound);
  * ``local_search``      — EDF-seeded greedy + move/swap hill-climbing,
                            scales to paper-sized queues (Fig. 20);
  * ``solve``             — picks B&B when the instance is small enough.

When Eq. 12 is infeasible (demand > capacity), the paper falls back to
scale-up / EDF (§9); we return the minimum-violation assignment and flag
``feasible=False`` so the caller can trigger those actions.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Solver view of one request group."""
    group_id: int
    model: str
    slo: float                    # seconds from NOW (deadline slack)
    drain_time: Dict[int, float]  # instance -> C (Eq. 5, RWT group bound)
    size: float = 1.0             # pending requests (for the count objective)


@dataclasses.dataclass(frozen=True)
class InstanceSpec:
    instance_id: int
    current_model: Optional[str]
    swap_time: Dict[str, float]   # model -> S on this instance


@dataclasses.dataclass
class Solution:
    assignment: List[List[int]]   # per instance: ordered group indices
    violation: float              # Σ max(0, p)
    total_penalty: float          # Σ p  (Eq. 13)
    feasible: bool                # Eq. 12 satisfied
    nodes_explored: int = 0

    def order_for(self, instance_idx: int) -> List[int]:
        return self.assignment[instance_idx]


def evaluate(assignment: Sequence[Sequence[int]], groups: Sequence[GroupSpec],
             instances: Sequence[InstanceSpec],
             objective: str = "penalty") -> Tuple[float, float]:
    """Returns (primary, tiebreak).

    objective="penalty" (paper Eq. 13): primary = Σ max(0,p), tiebreak Σ p.
    objective="count" (beyond-paper): primary = Σ size·1[p>0] — attainment-
    aligned; an LP can't express it but the search-based solvers can.
    """
    violation = 0.0
    total = 0.0
    count = 0.0
    for qi, order in enumerate(assignment):
        inst = instances[qi]
        t = 0.0
        cur = inst.current_model
        for gi in order:
            g = groups[gi]
            if g.model != cur:
                t += inst.swap_time.get(g.model, 0.0)  # Eq. 9/10 swap term
                cur = g.model
            t += g.drain_time[inst.instance_id]        # Eq. 10 completion term
            p = t - g.slo                               # Eq. 11
            total += p
            if p > 0:
                violation += p
                count += getattr(g, "size", 1.0) or 1.0
    if objective == "count":
        return count, violation
    return violation, total


def per_instance_makespan(assignment: Sequence[Sequence[int]],
                          groups: Sequence[GroupSpec],
                          instances: Sequence[InstanceSpec]) -> List[float]:
    """Estimated finish time of each instance's queue under an assignment
    (the Eq. 10 walk without the penalty fold).  Load-balance metric for
    the routing comparison (``core/routing.py`` computes the same vector
    for live virtual queues): the spread between the max and min entries
    is the wall-clock an idle instance spends waiting on a loaded one."""
    out: List[float] = []
    for qi, order in enumerate(assignment):
        inst = instances[qi]
        t = 0.0
        cur = inst.current_model
        for gi in order:
            g = groups[gi]
            if g.model != cur:
                t += inst.swap_time.get(g.model, 0.0)
                cur = g.model
            t += g.drain_time[inst.instance_id]
        out.append(t)
    return out


def _objective(assignment, groups, instances,
               objective: str = "penalty") -> Tuple[float, float]:
    return evaluate(assignment, groups, instances, objective)


# ---------------------------------------------------------------------------
# exact: branch and bound
# ---------------------------------------------------------------------------

def branch_and_bound(groups: Sequence[GroupSpec],
                     instances: Sequence[InstanceSpec],
                     node_limit: int = 500_000,
                     incumbent: Optional[Solution] = None) -> Solution:
    """Exact insertion-based DFS.

    Groups are placed one at a time (EDF consideration order for good early
    incumbents); each step tries every (queue, position) INSERTION, so all
    per-queue permutations are reachable — unlike append-only search, which
    can miss swap-saving reorderings.  Pruning uses the fact that adding a
    group never decreases any already-placed group's waiting time, so the
    partial violation Σ max(0,p) is a valid lower bound.
    """
    order = sorted(range(len(groups)), key=lambda i: groups[i].slo)
    G = len(instances)
    best: Optional[Tuple[float, float, List[List[int]]]] = None
    if incumbent is not None:
        best = (incumbent.violation, incumbent.total_penalty,
                [list(q) for q in incumbent.assignment])
    nodes = 0
    limit_hit = False

    def dfs(idx: int, assignment: List[List[int]]):
        nonlocal best, nodes, limit_hit
        nodes += 1
        if nodes > node_limit:
            limit_hit = True
            return
        viol, pen = evaluate(assignment, groups, instances)
        if best is not None and viol > best[0] + 1e-12:
            return  # lower bound prune
        if idx == len(order):
            key = (viol, pen)
            if best is None or key < (best[0], best[1]):
                best = (viol, pen, [list(q) for q in assignment])
            return
        gi = order[idx]
        for qi in range(G):
            for pos in range(len(assignment[qi]) + 1):
                assignment[qi].insert(pos, gi)
                dfs(idx + 1, assignment)
                assignment[qi].pop(pos)

    dfs(0, [[] for _ in range(G)])
    assert best is not None
    viol, pen, assign = best
    return Solution(assignment=assign, violation=viol, total_penalty=pen,
                    feasible=(viol <= 1e-9),
                    nodes_explored=nodes)


# ---------------------------------------------------------------------------
# scalable: EDF-seeded greedy + local search
# ---------------------------------------------------------------------------

def _greedy_seed(groups, instances) -> List[List[int]]:
    """EDF over groups; each group goes to the queue where it finishes
    earliest — with the model-affinity bonus the Oracle policy of Insight #3
    exploits (placing same-model groups together avoids the swap)."""
    order = sorted(range(len(groups)), key=lambda i: groups[i].slo)
    assignment: List[List[int]] = [[] for _ in instances]
    tails = [(0.0, inst.current_model) for inst in instances]
    for gi in order:
        g = groups[gi]
        best_qi, best_finish = 0, math.inf
        for qi, inst in enumerate(instances):
            t, cur = tails[qi]
            dt = inst.swap_time.get(g.model, 0.0) if g.model != cur else 0.0
            finish = t + dt + g.drain_time[inst.instance_id]
            if finish < best_finish:
                best_qi, best_finish = qi, finish
        assignment[best_qi].append(gi)
        inst = instances[best_qi]
        t, cur = tails[best_qi]
        dt = inst.swap_time.get(g.model, 0.0) if g.model != cur else 0.0
        tails[best_qi] = (t + dt + g.drain_time[inst.instance_id], g.model)
    return assignment


def local_search(groups: Sequence[GroupSpec], instances: Sequence[InstanceSpec],
                 max_iters: int = 2000, seed: int = 0,
                 init: Optional[List[List[int]]] = None,
                 objective: str = "penalty") -> Solution:
    rng = random.Random(seed)
    assignment = init if init is not None else _greedy_seed(groups, instances)
    assignment = [list(q) for q in assignment]
    best_v, best_p = _objective(assignment, groups, instances, objective)

    n = len(groups)
    G = len(instances)
    patience = max(200, 5 * n)
    iters_without_improvement = 0
    it = 0
    while it < max_iters and iters_without_improvement < patience and n > 0:
        it += 1
        move_kind = rng.random()
        snapshot = [list(q) for q in assignment]
        if move_kind < 0.5 and n >= 2:
            # swap two groups (possibly across queues)
            q1 = rng.randrange(G)
            q2 = rng.randrange(G)
            if not assignment[q1] or not assignment[q2]:
                continue
            i1 = rng.randrange(len(assignment[q1]))
            i2 = rng.randrange(len(assignment[q2]))
            if q1 == q2 and i1 == i2:
                continue
            assignment[q1][i1], assignment[q2][i2] = assignment[q2][i2], assignment[q1][i1]
        else:
            # move one group to a random (queue, position)
            q1 = rng.randrange(G)
            if not assignment[q1]:
                continue
            i1 = rng.randrange(len(assignment[q1]))
            gi = assignment[q1].pop(i1)
            q2 = rng.randrange(G)
            i2 = rng.randrange(len(assignment[q2]) + 1)
            assignment[q2].insert(i2, gi)
        v, p = _objective(assignment, groups, instances, objective)
        if (v, p) < (best_v, best_p):
            best_v, best_p = v, p
            iters_without_improvement = 0
        else:
            assignment = snapshot
            iters_without_improvement += 1

    if objective != "penalty":
        best_v, best_p = evaluate(assignment, groups, instances)
    return Solution(assignment=assignment, violation=best_v,
                    total_penalty=best_p, feasible=(best_v <= 1e-9),
                    nodes_explored=it)


def brute_force(groups: Sequence[GroupSpec],
                instances: Sequence[InstanceSpec]) -> Solution:
    """Exhaustive (test oracle, ≤ ~6 groups)."""
    n, G = len(groups), len(instances)
    best = None
    for queue_of in itertools.product(range(G), repeat=n):
        per_queue: List[List[int]] = [[] for _ in range(G)]
        for gi, qi in enumerate(queue_of):
            per_queue[qi].append(gi)
        for perms in itertools.product(*[itertools.permutations(q) for q in per_queue]):
            assignment = [list(p) for p in perms]
            key = _objective(assignment, groups, instances)
            if best is None or key < best[0]:
                best = (key, [list(q) for q in assignment])
    (v, p), assign = best
    return Solution(assignment=assign, violation=v, total_penalty=p,
                    feasible=(v <= 1e-9))


def solve(groups: Sequence[GroupSpec], instances: Sequence[InstanceSpec],
          *, exact_threshold: int = 0, seed: int = 0,
          node_limit: int = 100_000, objective: str = "penalty") -> Solution:
    """Paper's global scheduler entry point.

    Default is the scalable local search (the paper's production budget is
    ~5 ms per request group, Fig. 20); ``exact_threshold`` > 0 enables the
    exact B&B for small instances (tests / small clusters), seeded with the
    local-search incumbent so pruning bites immediately.
    """
    if not groups:
        return Solution([[] for _ in instances], 0.0, 0.0, True)
    # search budget scales with the decision space (Fig. 19: smaller δ =>
    # more groups => more solver work for the same decision quality)
    iters = max(2000, 40 * len(groups))
    ls = local_search(groups, instances, seed=seed, objective=objective,
                      max_iters=iters)
    if len(groups) <= min(exact_threshold, 7) and len(instances) <= 4:
        bb = branch_and_bound(groups, instances, node_limit=node_limit,
                              incumbent=ls)
        if (bb.violation, bb.total_penalty) < (ls.violation, ls.total_penalty):
            return bb
    return ls
