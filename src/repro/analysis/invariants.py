"""Runtime invariant checker for the serving stack.

The static pass (``repro.analysis.lint``) enforces *conventions* the hot
path depends on; this module checks the *state machines* those
conventions protect, at the only moments they are supposed to be
consistent: engine round boundaries (``step()`` / ``steps()`` return) and
controller ticks.  Every check raises ``InvariantViolation`` with an
actionable message naming the block / sequence / group involved.

Checked invariants
------------------
``check_block_manager`` (BlockManager, after any allocation-state-machine
transition):

  * **conservation** — every physical block is in exactly one of
    {free list, freed-but-cached, live (refcount >= 1)}, and the three
    partitions sum to ``num_blocks``;
  * **refcount accounting** — ``ref[b] ==`` (number of sequence block
    tables containing ``b``) + snapshot pins on ``b``;
  * **no-freed-while-referenced** — free/cached blocks have refcount 0
    and appear in no block table and hold no pins;
  * **prefix-index <-> block bijection** — ``_index`` and ``_block_key``
    are exact inverses, indexed chains are rooted (parent indexed or
    ``-1``), and indexed blocks are live or cached;
  * **pin lifecycle** — pins are positive and never exceed the block's
    refcount (each pin is one unit of refcount);
  * **allocation arithmetic** — ``len(block_table) ==
    blocks_needed(num_tokens)`` for every live sequence, no duplicate
    blocks within a table;
  * **incremental slot table** — every bound row mirrors its sequence's
    block table exactly (sentinel-padded), unbound rows are all-sentinel,
    no two sequences share a row.

``check_engine`` (engine, at round boundaries only — mid-round the
per-slot counters are legitimately in motion):

  * block-manager checks above, plus:
  * every active slot's request has a live allocation bound to that slot
    row; no request occupies two slots;
  * empty slots have zero length / prefill position;
  * decode-ready slots hold exactly ``lengths + 1`` KV tokens (the next
    decode step's write slot is always reserved — the contract
    ``_plan_burst`` and ``append_token`` maintain);
  * mid-prefill slots have ``lengths == prefill_pos`` and an allocation
    covering at least the prefilled run;
  * the incremental slot table equals a from-scratch
    ``_block_table_array()`` rebuild.

``check_queue_layer`` (QLMController, at ticks):

  * **no stranded groups** — every not-done group is reachable from
    exactly one virtual queue, and every not-done group sitting in a VQ
    is known to the controller;
  * **single ownership** — every non-terminal queued request belongs to
    exactly one group;
  * **group homogeneity** — members match the group's model, carry its
    ``group_id``, and the group SLO is the member minimum (the
    conservative deadline the RWT walk schedules against);
  * **dead instances hold nothing** — a DEAD instance's virtual queue is
    empty (``mark_dead`` empties it; the scheduler must never re-place
    onto it).

``check_terminal_states`` (QLMController, at ticks — the fault-tolerance
conservation law):

  * every tracked request is in exactly one of {queued-in-placed-group,
    engine-resident, finished, rejected, failed-quarantined};
  * a waiting (non-terminal, not in-flight) request belongs to a group
    reachable from an alive virtual queue — engine death redelivers or
    quarantines, it never silently strands work;
  * with engine handles attached, an in-flight request is resident in an
    ALIVE engine (slot or pushback) — no ``_in_flight=True`` limbo.

Enabling
--------
``QLINT_INVARIANTS=1`` (env) or ``EngineConfig.debug_invariants=True`` /
``QLMConfig.debug_invariants=True``.  ``QLINT_INVARIANTS_SAMPLE=N``
checks every Nth round instead of all of them (cheap sampled mode for
benches; default 1 = every round).  ``tests/conftest.py`` honors the env
var by wrapping the engine round loop and every BlockManager transition,
so the whole tier-1 suite doubles as an invariant suite.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional

import numpy as np


class InvariantViolation(AssertionError):
    """A serving-stack invariant does not hold.  The message names the
    block / sequence / slot / group involved and the check that failed."""


def invariants_enabled() -> bool:
    return os.environ.get("QLINT_INVARIANTS", "").strip().lower() \
        not in ("", "0", "false", "off", "no")


def sample_every() -> int:
    """Check every Nth round (QLINT_INVARIANTS_SAMPLE, default 1)."""
    try:
        return max(1, int(os.environ.get("QLINT_INVARIANTS_SAMPLE", "1")))
    except ValueError:
        return 1


class InvariantSampler:
    """Counter-based sampling: ``due()`` is True every Nth call.

    Thread-safe: one sampler is shared by every hooked BlockManager
    mutator and engine round across the threaded cluster's agent
    threads, and a racy ``+=`` would silently drift the sampling period
    (or double-fire the due slot)."""

    def __init__(self, every: Optional[int] = None):
        self.every = sample_every() if every is None else max(1, every)
        self._n = 0
        self._lock = threading.Lock()

    def due(self) -> bool:
        with self._lock:
            self._n += 1
            return self._n % self.every == 0


def _fail(where: str, msg: str) -> None:
    raise InvariantViolation(f"[{where}] {msg}")


# ---------------------------------------------------------------------------
# BlockManager
# ---------------------------------------------------------------------------
def check_block_manager(bm: Any, *, where: str = "block-manager") -> None:
    n = bm.num_blocks
    free = list(bm._free)
    cached = list(bm._cached)
    ref = bm._ref
    pins: Dict[int, int] = bm._pins

    # ownership map: block -> sequence ids whose table contains it
    owners: Dict[int, List[int]] = {}
    for sid, alloc in bm._seqs.items():
        seen = set()
        for b in alloc.block_table:
            if b in seen:
                _fail(where, f"seq {sid} lists block {b} twice in its "
                             f"block table {alloc.block_table}")
            seen.add(b)
            owners.setdefault(b, []).append(sid)

    # conservation: free / cached / live partition the pool exactly
    free_set, cached_set = set(free), set(cached)
    if len(free_set) != len(free):
        dupes = sorted(b for b in free_set if free.count(b) > 1)
        _fail(where, f"free list contains duplicates: {dupes}")
    if free_set & cached_set:
        _fail(where, f"blocks both free and cached: "
                     f"{sorted(free_set & cached_set)}")
    # no-freed-while-referenced (checked before the conservation count so a
    # double-free names the block and its owner instead of a bare tally)
    for b in free + cached:
        if b in owners:
            _fail(where, f"block {b} was freed while still referenced by "
                         f"seq(s) {owners[b]}")
        if int(ref[b]) != 0:
            _fail(where, f"block {b} is on the "
                         f"{'cached' if b in cached_set else 'free'} list "
                         f"but has refcount {int(ref[b])}")
        if pins.get(b):
            _fail(where, f"block {b} was freed while still pinned "
                         f"({pins[b]} snapshot pin(s))")

    live = [b for b in range(n) if int(ref[b]) >= 1]
    if len(free) + len(cached) + len(live) != n:
        lost = sorted(set(range(n)) - free_set - cached_set - set(live))
        detail = str(lost) if lost else "by double-count"
        _fail(where,
              f"block conservation broken: free={len(free)} + "
              f"cached={len(cached)} + live={len(live)} != "
              f"num_blocks={n} (leaked/overlapping blocks: {detail})")

    # refcount accounting: ref == table occurrences + pins
    for b in range(n):
        expect = len(owners.get(b, ())) + pins.get(b, 0)
        if int(ref[b]) != expect:
            _fail(where,
                  f"block {b}: refcount {int(ref[b])} != "
                  f"{len(owners.get(b, ()))} table reference(s) "
                  f"(seqs {owners.get(b, [])}) + {pins.get(b, 0)} pin(s)")

    # pin lifecycle
    for b, p in pins.items():
        if p <= 0:
            _fail(where, f"block {b} has non-positive pin count {p}")
        if int(ref[b]) < p:
            _fail(where, f"block {b}: {p} pin(s) exceed refcount "
                         f"{int(ref[b])}")

    # prefix index <-> block bijection
    for key, b in bm._index.items():
        if bm._block_key.get(b) != key:
            _fail(where,
                  f"prefix index names block {b} for key {key!r} but the "
                  f"block maps back to {bm._block_key.get(b)!r}")
        parent = key[0]
        if parent != -1 and parent not in bm._block_key:
            _fail(where, f"indexed block {b} chains through parent "
                         f"{parent} which is not indexed (orphaned chain)")
        if int(ref[b]) == 0 and b not in cached_set:
            _fail(where, f"indexed block {b} is neither live nor cached")
    for b, key in bm._block_key.items():
        if bm._index.get(key) != b:
            _fail(where, f"block {b} claims prefix key {key!r} but the "
                         f"index maps it to {bm._index.get(key)}")
    for b in cached:
        if b not in bm._block_key:
            _fail(where, f"cached block {b} is not in the prefix index "
                         f"(cache_freed keeps only indexed blocks)")

    # allocation arithmetic
    for sid, alloc in bm._seqs.items():
        need = bm.blocks_needed(alloc.num_tokens)
        if len(alloc.block_table) != need:
            _fail(where,
                  f"seq {sid}: {len(alloc.block_table)} block(s) allocated "
                  f"but {alloc.num_tokens} token(s) need {need}")

    # pending COW destinations must be live (the engine has not yet copied
    # the page contents; a freed dst would hand the page to a new owner
    # before the copy lands)
    for src, dst in bm._cow_ops:
        if int(ref[dst]) < 1:
            _fail(where, f"pending COW op ({src} -> {dst}) targets a freed "
                         f"destination block")

    # incremental slot table mirrors the per-seq tables
    table = bm._table
    if table is not None:
        sentinel = n
        row_owner: Dict[int, int] = {}
        for sid, row in bm._seq_rows.items():
            if sid not in bm._seqs:
                _fail(where, f"slot table row {row} bound to unknown seq "
                             f"{sid}")
            if row in row_owner:
                _fail(where, f"slot table row {row} bound to both seq "
                             f"{row_owner[row]} and seq {sid}")
            row_owner[row] = sid
            bt = bm._seqs[sid].block_table
            got = [int(x) for x in table[row, :len(bt)]]
            if got != bt:
                _fail(where,
                      f"slot table row {row} desynced for seq {sid}: "
                      f"table={got} vs block_table={bt}")
            if not (table[row, len(bt):] == sentinel).all():
                _fail(where,
                      f"slot table row {row} (seq {sid}) has stale entries "
                      f"past the allocation: {table[row, len(bt):]}")
        for row in range(table.shape[0]):
            if row not in row_owner and not (table[row] == sentinel).all():
                _fail(where,
                      f"unbound slot table row {row} is not all-sentinel: "
                      f"{table[row]}")


# ---------------------------------------------------------------------------
# Engine (round boundaries)
# ---------------------------------------------------------------------------
def check_engine(engine: Any, *, where: str = "engine") -> None:
    bm = engine.block_mgr
    check_block_manager(bm, where=f"{where}/block-manager")

    seen_req: Dict[int, int] = {}
    for i, req in enumerate(engine.slots):
        if req is None:
            if int(engine.lengths[i]) != 0 or int(engine.prefill_pos[i]) != 0:
                _fail(where,
                      f"empty slot {i} has length {int(engine.lengths[i])} "
                      f"/ prefill_pos {int(engine.prefill_pos[i])}")
            continue
        if req.req_id in seen_req:
            _fail(where, f"request {req.req_id} occupies both slot "
                         f"{seen_req[req.req_id]} and slot {i}")
        seen_req[req.req_id] = i
        if not bm.has(req.req_id):
            _fail(where, f"slot {i} holds request {req.req_id} with no "
                         f"KV allocation")
        if bm._table is not None:
            row = bm._seq_rows.get(req.req_id)
            if row != i:
                _fail(where, f"request {req.req_id} sits in slot {i} but "
                             f"its slot-table row is {row}")
        length = int(engine.lengths[i])
        ppos = int(engine.prefill_pos[i])
        kv = bm.seq_tokens(req.req_id)
        if not 0 <= ppos <= req.prompt_len:
            _fail(where, f"slot {i} (req {req.req_id}): prefill_pos {ppos} "
                         f"outside [0, prompt_len={req.prompt_len}]")
        if ppos >= req.prompt_len:
            # decode-ready: the next decode step's KV slot is reserved
            if kv != length + 1:
                _fail(where,
                      f"slot {i} (req {req.req_id}) decode-ready with "
                      f"{kv} KV token(s) allocated but length {length} "
                      f"(expected length + 1 = {length + 1}: the next "
                      f"write slot must be reserved)")
        else:
            if length != ppos:
                _fail(where,
                      f"slot {i} (req {req.req_id}) mid-prefill with "
                      f"length {length} != prefill_pos {ppos}")
            if not ppos <= kv <= req.prompt_len + 1:
                _fail(where,
                      f"slot {i} (req {req.req_id}) mid-prefill at "
                      f"{ppos}/{req.prompt_len} but allocation covers "
                      f"{kv} token(s)")

    # incremental slot table == from-scratch rebuild (the reference path)
    if getattr(engine.cfg, "incremental_block_table", False) \
            and bm.slot_table() is not None:
        rebuilt = engine._block_table_array()
        incremental = bm.slot_table()
        if not np.array_equal(incremental, rebuilt):
            bad = [r for r in range(rebuilt.shape[0])
                   if not (incremental[r] == rebuilt[r]).all()]
            detail = "; ".join(
                f"row {r}: incremental={incremental[r].tolist()} vs "
                f"rebuild={rebuilt[r].tolist()}" for r in bad[:4])
            _fail(where,
                  f"incremental slot table diverged from from-scratch "
                  f"rebuild on row(s) {bad}: {detail}")


# ---------------------------------------------------------------------------
# Queue layer (controller ticks)
# ---------------------------------------------------------------------------
def _alive_flags(controller: Any) -> List[bool]:
    """Per-instance liveness; controllers without supervision (pre-fault-
    tolerance callers, stub controllers in tests) read as all-alive.
    DRAINING counts alive (its residents are still finishing); DEAD and
    DRAINED are departed."""
    n = len(controller.instances)
    health = getattr(controller, "health", None)
    if health is None:
        return [True] * n
    flags = [h.state not in ("dead", "drained") for h in health]
    # callers may grow controller.instances after construction (tests,
    # scale-up): unsupervised extras read as alive
    flags += [True] * (n - len(flags))
    return flags[:n]


def check_queue_layer(controller: Any, *, where: str = "queue-layer") -> None:
    # placement: group -> virtual queues that can reach it
    alive = _alive_flags(controller)
    placements: Dict[int, List[int]] = {}
    vq_groups: List[Any] = []
    for idx, inst in enumerate(controller.instances):
        vq = inst.virtual_queue
        if not alive[idx]:
            undone = [g for g in vq.groups if not g.done()]
            if undone:
                _fail(where,
                      f"departed (dead/drained) instance "
                      f"{vq.instance_id} still holds {len(undone)} "
                      f"group(s) {[g.group_id for g in undone]}: "
                      f"mark_dead/_finish_drains must empty the virtual "
                      f"queue and nothing may re-place onto a departed "
                      f"instance")
            continue
        for g in vq.groups:
            placements.setdefault(id(g), []).append(vq.instance_id)
            vq_groups.append(g)

    known = {id(g) for g in controller.groups}
    for g in controller.groups:
        if g.done():
            continue
        homes = placements.get(id(g), [])
        if not homes:
            _fail(where,
                  f"group {g.group_id} (model {g.model}, "
                  f"{g.num_pending()} pending) is stranded: reachable "
                  f"from no virtual queue")
        if len(homes) > 1:
            _fail(where,
                  f"group {g.group_id} (model {g.model}) is placed in "
                  f"{len(homes)} virtual queues: instances {homes}")
    for g in vq_groups:
        if not g.done() and id(g) not in known:
            _fail(where,
                  f"virtual queue holds group {g.group_id} "
                  f"(model {g.model}) unknown to the controller")

    # single ownership: every non-terminal queued request in exactly one
    # group (by identity — req_id labels alone can go stale on re-group)
    membership: Dict[int, List[int]] = {}
    for g in controller.groups:
        for r in g.requests:
            membership.setdefault(id(r), []).append(g.group_id)
    for r in controller.global_queue:
        if r.finished():
            continue
        owners = membership.get(id(r), [])
        if len(owners) != 1:
            _fail(where,
                  f"request {r.req_id} (model {r.model}, slo {r.slo}) is "
                  f"owned by {len(owners)} group(s) {owners}; every "
                  f"non-terminal request must be reachable from exactly "
                  f"one virtual queue")

    # group homogeneity + conservative SLO
    for g in controller.groups:
        for r in g.requests:
            if r.model != g.model:
                _fail(where,
                      f"group {g.group_id} (model {g.model}) contains "
                      f"request {r.req_id} for model {r.model}")
            if r.group_id != g.group_id:
                _fail(where,
                      f"request {r.req_id} in group {g.group_id} carries "
                      f"stale group_id {r.group_id}")
        if g.requests:
            mn = min(r.slo for r in g.requests)
            if g.slo != mn:
                _fail(where,
                      f"group {g.group_id} SLO {g.slo} != member minimum "
                      f"{mn} (the RWT walk would schedule against the "
                      f"wrong deadline)")


# ---------------------------------------------------------------------------
# Terminal-state conservation (fault tolerance: §4 "the global queue is
# the durable request store")
# ---------------------------------------------------------------------------
def check_terminal_states(controller: Any, engines: Optional[List[Any]] = None,
                          *, where: str = "terminal-states") -> None:
    """Every submitted request is in exactly one of
    {queued-in-placed-group, engine-resident, finished, rejected,
    failed-quarantined} at tick boundaries.

    ``engines`` (index-aligned with ``controller.instances``) enables the
    residency cross-check: an ``_in_flight`` request must actually sit in
    an ALIVE engine's slots or pushback — the state engine failure paths
    are most likely to strand.  Terminal requests are classified before
    ``_in_flight`` is consulted (the engine's finish path leaves the flag
    set on completed requests by design)."""
    alive = _alive_flags(controller)

    # group membership over not-done groups with an alive placement
    placed: Dict[int, bool] = {}
    for idx, inst in enumerate(controller.instances):
        if not alive[idx]:
            continue
        for g in inst.virtual_queue.groups:
            placed[id(g)] = True
    member_placed: Dict[int, List[int]] = {}
    for g in controller.groups:
        if g.done():
            continue
        for r in g.requests:
            if placed.get(id(g), False):
                member_placed.setdefault(id(r), []).append(g.group_id)

    # residency over alive engines (slots + pushback limbo)
    resident: Dict[int, str] = {}
    if engines is not None:
        for idx, eng in enumerate(engines):
            if eng is None or not alive[idx]:
                continue
            for slot, r in enumerate(eng.slots):
                if r is not None:
                    resident[id(r)] = f"engine {idx} slot {slot}"
            pushed = getattr(eng, "_pushback", None)
            if pushed is not None:
                resident[id(pushed)] = f"engine {idx} pushback"

    failed_ids = {id(r) for r in getattr(controller, "failed", ())}
    for r in controller.global_queue + controller.finished \
            + controller.rejected:
        rid = f"request {r.req_id} (model {r.model}, slo {r.slo})"
        terminal = [s for s, on in (("rejected", r.rejected),
                                    ("failed", getattr(r, "failed", False)),
                                    ("finished", r.finished())) if on]
        if terminal:
            # exactly-one is state-machine exactness, not double counting:
            # attainment already scores failed-first.  rejected+finished
            # is legal (rejections are stamped finished); failed+rejected
            # would double-classify.
            if r.rejected and getattr(r, "failed", False):
                _fail(where, f"{rid} is both rejected (never admitted) and "
                             f"failed-quarantined (admitted, then poisoned)")
            if not r.finished():
                _fail(where, f"{rid} is {terminal[0]} but has no "
                             f"completion_time: group cursors will never "
                             f"skip it (liveness leak)")
            if getattr(r, "failed", False) and id(r) not in failed_ids:
                _fail(where, f"{rid} is failed-quarantined but missing "
                             f"from controller.failed (stats desync)")
            continue
        if getattr(r, "_in_flight", False):
            if engines is not None and id(r) not in resident:
                _fail(where,
                      f"{rid} is marked _in_flight but resident in no "
                      f"alive engine (slot or pushback): a failure path "
                      f"returned it to the queue without clearing the "
                      f"flag, so no agent will ever pull it again")
            continue
        # waiting: must be reachable from exactly one alive virtual queue
        owners = member_placed.get(id(r), [])
        if len(owners) != 1:
            state = ("stranded: member of no group placed on an alive "
                     "instance" if not owners else
                     f"placed {len(owners)} times: groups {owners}")
            _fail(where, f"{rid} is waiting (non-terminal, not in flight) "
                         f"but {state} — engine death must redeliver or "
                         f"quarantine every in-flight request")


# ---------------------------------------------------------------------------
# Cross-engine snapshot migration (self-healing cluster lifecycle)
# ---------------------------------------------------------------------------
def check_migration(controller: Any, engines: Optional[List[Any]] = None,
                    *, where: str = "migration") -> None:
    """Migration-state conservation at tick boundaries:

    * a request is RESIDENT (slot or pushback) on at most one engine —
      a migrated request must not be running on both its source and its
      destination;
    * a resident request carries no live-pinned snapshot — once the
      destination's pages are live, the source's pins must have been
      released (transferred on same-engine resume, materialized away on
      migration), otherwise the source pool pins pages forever;
    * a QUEUED request's pinned snapshot must point at an ALIVE attached
      engine's current pool and epoch — pins into a departed or reset
      pool are dangling (mark_dead / migration_sweep must release them
      and restart the request).
    """
    alive = _alive_flags(controller)
    if engines is not None:
        homes: Dict[int, List[str]] = {}
        for idx, eng in enumerate(engines):
            if eng is None or idx >= len(alive) or not alive[idx]:
                continue
            for slot, r in enumerate(eng.slots):
                if r is not None:
                    homes.setdefault(id(r), []).append(
                        f"engine {idx} slot {slot}")
            pushed = getattr(eng, "_pushback", None)
            if pushed is not None:
                homes.setdefault(id(pushed), []).append(
                    f"engine {idx} pushback")
        by_id = {}
        for eng in engines:
            if eng is None:
                continue
            for r in list(eng.slots) + [getattr(eng, "_pushback", None)]:
                if r is not None:
                    by_id[id(r)] = r
        for rid, places in homes.items():
            if len(places) > 1:
                r = by_id[rid]
                _fail(where,
                      f"request {r.req_id} (model {r.model}) is resident "
                      f"in {len(places)} engines at once: {places} — a "
                      f"migrated request must run on exactly one engine")
            r = by_id[rid]
            snap = getattr(r, "snapshot", None)
            if isinstance(snap, dict) and snap.get("pinned"):
                _fail(where,
                      f"request {r.req_id} is resident ({places[0]}) but "
                      f"its snapshot still pins {len(snap['pinned'])} "
                      f"block(s) in a source pool: source pins must be "
                      f"released iff destination pages are live")

    # queued pinned snapshots must have a live owner pool + epoch
    pools = {}
    if engines is not None:
        for idx, eng in enumerate(engines):
            bm = getattr(eng, "block_mgr", None)
            if bm is not None:
                pools[id(bm)] = (idx, bm)
    for r in controller.global_queue:
        if r.finished() or getattr(r, "_in_flight", False):
            continue
        snap = getattr(r, "snapshot", None)
        if not isinstance(snap, dict) or not snap.get("pinned"):
            continue
        owner = snap.get("pin_owner")
        entry = pools.get(id(owner)) if engines is not None else None
        if engines is None:
            continue   # no residency info: owner liveness unknowable here
        if entry is None or entry[0] >= len(alive) or not alive[entry[0]]:
            _fail(where,
                  f"request {r.req_id} (model {r.model}) holds a snapshot "
                  f"pinned in a departed/unattached pool: mark_dead or "
                  f"the migration sweep must release dead pins and "
                  f"restart the request")
        elif snap.get("pin_epoch") != getattr(owner, "epoch", None):
            _fail(where,
                  f"request {r.req_id} (model {r.model}) holds a snapshot "
                  f"pinned at a stale pool epoch "
                  f"{snap.get('pin_epoch')} != {getattr(owner, 'epoch', None)}: "
                  f"the pages were reset under it")


# ---------------------------------------------------------------------------
# Test-suite hooks (tests/conftest.py honors QLINT_INVARIANTS=1)
# ---------------------------------------------------------------------------
_BM_MUTATORS = ("allocate", "extend", "append_token", "free",
                "share_prefix", "fork", "evict_split", "resume_pinned",
                "release_pins", "register_prefix", "bind_slot", "reset")
_ENGINE_ROUNDS = ("step", "steps")


def install_test_hooks() -> None:
    """Wrap every BlockManager transition and engine round boundary with
    the invariant checks (idempotent).  Used by ``tests/conftest.py`` when
    ``QLINT_INVARIANTS=1`` so the whole tier-1 suite doubles as an
    invariant suite — no per-test opt-in required."""
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.kv_cache import BlockManager

    if getattr(BlockManager, "_qlint_hooked", False):
        return
    BlockManager._qlint_hooked = True
    ContinuousBatchingEngine._qlint_hooked = True
    sampler = InvariantSampler()

    def _wrap_bm(name):
        orig = getattr(BlockManager, name)

        def checked(self, *a, **kw):
            out = orig(self, *a, **kw)
            if sampler.due():
                check_block_manager(
                    self, where=f"QLINT_INVARIANTS/BlockManager.{name}")
            return out

        checked.__name__ = orig.__name__
        checked.__qualname__ = orig.__qualname__
        setattr(BlockManager, name, checked)

    def _wrap_round(name):
        orig = getattr(ContinuousBatchingEngine, name)

        def checked(self, *a, **kw):
            out = orig(self, *a, **kw)
            check_engine(self, where=f"QLINT_INVARIANTS/engine.{name}")
            return out

        checked.__name__ = orig.__name__
        checked.__qualname__ = orig.__qualname__
        setattr(ContinuousBatchingEngine, name, checked)

    for name in _BM_MUTATORS:
        _wrap_bm(name)
    for name in _ENGINE_ROUNDS:
        _wrap_round(name)
