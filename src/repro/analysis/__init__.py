"""qlint: static analysis + runtime invariant checking for the serving
stack.

Two cooperating layers:

  * ``repro.analysis.lint`` — an AST-based static pass with JAX/Pallas
    specific rules (host syncs in the device-resident hot loop, buffer
    donation misuse, retrace hazards, blocking calls in coroutines,
    traced-value branches in Pallas kernel bodies, unguarded ratio
    statistics).  CLI: ``python -m repro.analysis.lint src/``.
  * ``repro.analysis.invariants`` — a runtime checker for the
    ``BlockManager`` / engine / queue-layer invariants the static rules
    cannot see, callable at engine round boundaries and controller
    ticks; enabled via ``EngineConfig.debug_invariants`` or
    ``QLINT_INVARIANTS=1``.

See ``docs/analysis.md`` for the rule catalogue and waiver syntax.
"""
from repro.analysis.invariants import (InvariantViolation,
                                       check_block_manager, check_engine,
                                       check_queue_layer, invariants_enabled)

__all__ = [
    "InvariantViolation",
    "check_block_manager",
    "check_engine",
    "check_queue_layer",
    "invariants_enabled",
]
