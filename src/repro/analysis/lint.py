"""qlint — AST-based static analysis for the JAX/Pallas serving stack.

CLI::

    python -m repro.analysis.lint src/ [--json report.json]
        [--baseline qlint_baseline.json] [--write-baseline] [--self-test]

Rules (see ``docs/analysis.md`` for the full catalogue):

  host-sync-in-hot-path   device syncs (.item(), np.asarray, float()/int()
                          on jit outputs, jax.device_get, block_until_ready)
                          inside functions reachable from the engine round
                          entry points (steps/step/_decode_round/
                          _prefill_chunk_round/_decode_burst_round)
  use-after-donate        reading a name passed at a donate_argnums
                          position after the jitted call without rebinding
  retrace-hazard          unhashable / per-call-varying values at static
                          arg positions; jax.jit called inside a loop
  blocking-in-async       time.sleep, sync engine/agent calls, blocking
                          queue.Queue ops inside ``async def``
  pallas-traced-branch    Python ``if`` on a traced value inside a Pallas
                          kernel body (kernels/*.py)
  unguarded-div           ratio statistics dividing by a possibly-zero
                          counter without a guard
  waiver-missing-reason   a ``# qlint: disable=`` comment without
                          ``-- <reason>`` (waivers must be justified)

Waivers: ``# qlint: disable=<rule>[,rule] -- <reason>`` on the offending
line, or on its own line directly above.  The baseline file (JSON list of
fingerprints) makes the gate *zero NEW findings*; fingerprints are
line-number-free (``rule|path|message``) so unrelated edits don't churn
it.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import hashlib
import json
import os
import re
import sys
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = {
    "host-sync-in-hot-path":
        "host/device sync inside the engine's hot round loop",
    "use-after-donate":
        "donated buffer read after the jitted call without rebinding",
    "retrace-hazard":
        "jit static-arg value that forces recompilation every call",
    "blocking-in-async":
        "blocking call inside a coroutine",
    "pallas-traced-branch":
        "Python `if` on a traced value in a Pallas kernel body",
    "unguarded-div":
        "ratio statistic dividing by a possibly-zero counter",
    "waiver-missing-reason":
        "qlint waiver without a stated reason",
}

HOT_ENTRIES = {"step", "steps", "_decode_round", "_prefill_chunk_round",
               "_decode_burst_round"}
HOT_ANCHORS = {"_decode_round", "_prefill_chunk_round"}

_COUNTERISH = re.compile(
    r"(count|total|scored|served|reject|complet|finish|sample|request|"
    r"tick|round|seen|done|queued|pending|arrived|attempt|admitted|shed|"
    r"expired|cancel)", re.I)

_WAIVER_RE = re.compile(
    r"#\s*qlint:\s*disable=([A-Za-z0-9_\-, ]+?)\s*(?:--\s*(.*\S))?\s*$")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    waived: bool = False
    waive_reason: str = ""
    baselined: bool = False

    @property
    def fingerprint(self) -> str:
        raw = f"{self.rule}|{self.path}|{self.message}"
        return hashlib.sha1(raw.encode()).hexdigest()[:12]

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        tag = ""
        if self.waived:
            tag = f"  [waived: {self.waive_reason}]"
        elif self.baselined:
            tag = "  [baselined]"
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message}{tag}")


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _own_walk(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested defs."""
    stack: List[ast.AST] = [fn]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if node is not fn and isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is fn and child is not fn:
                continue
            stack.append(child)


def _write_targets(t: ast.AST) -> List[str]:
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, ast.Attribute):
        d = _dotted(t)
        return [d] if d else []
    if isinstance(t, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in t.elts:
            out.extend(_write_targets(e))
        return out
    if isinstance(t, ast.Starred):
        return _write_targets(t.value)
    return []  # Subscript store mutates, doesn't rebind


class FileCtx:
    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[int, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[id(child)] = node
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        self.waivers: Dict[int, Tuple[Set[str], str]] = {}
        self.findings: List[Finding] = []
        self._collect_waivers()

    def _collect_waivers(self) -> None:
        try:
            toks = list(tokenize.generate_tokens(
                iter(self.source.splitlines(True)).__next__))
        except tokenize.TokenizeError:
            return
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVER_RE.search(tok.string)
            if not m:
                continue
            line = tok.start[0]
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(2) or "").strip()
            if not reason:
                self.add("waiver-missing-reason", line, tok.start[1],
                         "waiver must state a reason: "
                         "`# qlint: disable=<rule> -- <why>`")
                continue
            standalone = self.source.splitlines()[line - 1].lstrip() \
                .startswith("#")
            target = line + 1 if standalone else line
            self.waivers.setdefault(target, (set(), reason))[0].update(rules)
            if not standalone:
                # trailing comment also covers a continuation line
                self.waivers.setdefault(line, (rules, reason))

    def resolve(self, dotted: Optional[str]) -> Optional[str]:
        if not dotted:
            return None
        head, _, rest = dotted.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    def add(self, rule: str, line: int, col: int, message: str) -> None:
        f = Finding(rule, self.rel, line, col, message)
        waiver = self.waivers.get(line)
        if waiver and rule in waiver[0] and rule != "waiver-missing-reason":
            f.waived, f.waive_reason = True, waiver[1]
        self.findings.append(f)


# ---------------------------------------------------------------------------
# linear execution-order events (for use-after-donate and guard checks)
# ---------------------------------------------------------------------------
def _expr_events(ctx: FileCtx, e: ast.AST,
                 jitted: Dict[str, Set[int]]) -> Iterable[tuple]:
    reads: List[tuple] = []
    calls: List[tuple] = []
    for n in ast.walk(e):
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Load):
            d = _dotted(n)
            if d:
                reads.append(("read", d, n))
        if isinstance(n, ast.Call):
            fd = _dotted(n.func)
            if fd in jitted:
                keys = []
                for pos in sorted(jitted[fd]):
                    if pos < len(n.args):
                        k = _dotted(n.args[pos])
                        if k:
                            keys.append(k)
                calls.append(("donate", keys, n))
    yield from reads
    yield from calls


def _linear(ctx: FileCtx, stmts: Sequence[ast.stmt],
            jitted: Dict[str, Set[int]]) -> Iterable[tuple]:
    for s in stmts:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        if isinstance(s, ast.Assign):
            yield from _expr_events(ctx, s.value, jitted)
            for t in s.targets:
                for k in _write_targets(t):
                    yield ("write", k, s)
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            yield from _expr_events(ctx, s.value, jitted)
            for k in _write_targets(s.target):
                yield ("write", k, s)
        elif isinstance(s, ast.AugAssign):
            yield from _expr_events(ctx, s.value, jitted)
            yield from _expr_events(ctx, s.target, jitted)
            for k in _write_targets(s.target):
                yield ("write", k, s)
        elif isinstance(s, (ast.Expr, ast.Return, ast.Raise, ast.Assert,
                            ast.Delete, ast.Await)):
            for field in ast.iter_child_nodes(s):
                yield from _expr_events(ctx, field, jitted)
        elif isinstance(s, ast.If):
            yield from _expr_events(ctx, s.test, jitted)
            yield from _linear(ctx, s.body, jitted)
            yield from _linear(ctx, s.orelse, jitted)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            yield from _expr_events(ctx, s.iter, jitted)
            for k in _write_targets(s.target):
                yield ("write", k, s)
            yield from _linear(ctx, s.body, jitted)
            yield from _linear(ctx, s.orelse, jitted)
        elif isinstance(s, ast.While):
            yield from _expr_events(ctx, s.test, jitted)
            yield from _linear(ctx, s.body, jitted)
            yield from _linear(ctx, s.orelse, jitted)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                yield from _expr_events(ctx, item.context_expr, jitted)
                if item.optional_vars is not None:
                    for k in _write_targets(item.optional_vars):
                        yield ("write", k, s)
            yield from _linear(ctx, s.body, jitted)
        elif isinstance(s, ast.Try):
            yield from _linear(ctx, s.body, jitted)
            for h in s.handlers:
                yield from _linear(ctx, h.body, jitted)
            yield from _linear(ctx, s.orelse, jitted)
            yield from _linear(ctx, s.finalbody, jitted)


# ---------------------------------------------------------------------------
# rule: host-sync-in-hot-path
# ---------------------------------------------------------------------------
def _module_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def _called_names(fn: ast.AST) -> Tuple[Set[str], Set[str]]:
    """(self-method names, bare function names) called from fn."""
    methods: Set[str] = set()
    bare: Set[str] = set()
    for n in _own_walk(fn):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            methods.add(f.attr)
        elif isinstance(f, ast.Name):
            bare.add(f.id)
    return methods, bare


def rule_host_sync(ctx: FileCtx) -> None:
    mod_fns = _module_functions(ctx.tree)
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {n.name: n for n in cls.body
                   if isinstance(n, ast.FunctionDef)}
        if not (HOT_ANCHORS & set(methods)):
            continue
        # BFS over self-calls + bare module-function calls
        hot: Dict[int, Tuple[str, ast.FunctionDef]] = {}
        work = [methods[m] for m in HOT_ENTRIES & set(methods)]
        for fn in work:
            hot[id(fn)] = (fn.name, fn)
        while work:
            fn = work.pop()
            m_calls, b_calls = _called_names(fn)
            for name in m_calls:
                tgt = methods.get(name)
                if tgt is not None and id(tgt) not in hot:
                    hot[id(tgt)] = (name, tgt)
                    work.append(tgt)
            for name in b_calls:
                tgt = mod_fns.get(name)
                if tgt is not None and id(tgt) not in hot:
                    hot[id(tgt)] = (name, tgt)
                    work.append(tgt)
        for name, fn in list(hot.values()):
            _scan_hot_fn(ctx, name, fn)


def _scan_hot_fn(ctx: FileCtx, name: str, fn: ast.FunctionDef) -> None:
    # names holding jit outputs / device arrays (local dataflow)
    device: Set[str] = set()
    for n in _own_walk(fn):
        if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
            fd = _dotted(n.value.func) or ""
            rd = ctx.resolve(fd) or ""
            if (fd.startswith("self._") and fd.endswith("_fn")) \
                    or rd.startswith("jax."):
                for t in n.targets:
                    for k in _write_targets(t):
                        device.add(k)
    where = f"in hot-path function `{name}`"
    for n in _own_walk(fn):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        rd = ctx.resolve(_dotted(f)) or ""
        if isinstance(f, ast.Attribute) and f.attr == "item" and not n.args:
            ctx.add("host-sync-in-hot-path", n.lineno, n.col_offset,
                    f".item() forces a device->host sync {where}")
        elif isinstance(f, ast.Attribute) and f.attr == "block_until_ready":
            ctx.add("host-sync-in-hot-path", n.lineno, n.col_offset,
                    f"block_until_ready() blocks on the device {where}")
        elif rd in ("numpy.asarray", "numpy.array"):
            ctx.add("host-sync-in-hot-path", n.lineno, n.col_offset,
                    f"{rd}() copies device memory to host {where}")
        elif rd in ("jax.device_get", "jax.block_until_ready"):
            ctx.add("host-sync-in-hot-path", n.lineno, n.col_offset,
                    f"{rd}() forces a device->host sync {where}")
        elif isinstance(f, ast.Name) and f.id in ("float", "int") \
                and len(n.args) == 1:
            k = _dotted(n.args[0])
            if k in device:
                ctx.add("host-sync-in-hot-path", n.lineno, n.col_offset,
                        f"{f.id}({k}) forces a device->host sync on a jit "
                        f"output {where}")


# ---------------------------------------------------------------------------
# rule: use-after-donate + retrace-hazard (shared jit collection)
# ---------------------------------------------------------------------------
def _const_positions(e: Optional[ast.AST],
                     env: Dict[str, ast.AST]) -> Optional[Set[int]]:
    if e is None:
        return None
    if isinstance(e, ast.Name) and e.id in env:
        return _const_positions(env[e.id], env)
    if isinstance(e, ast.Constant) and isinstance(e.value, int):
        return {e.value}
    if isinstance(e, ast.Tuple):
        out: Set[int] = set()
        for x in e.elts:
            if isinstance(x, ast.Constant) and isinstance(x.value, int):
                out.add(x.value)
            else:
                return None
        return out
    if isinstance(e, ast.IfExp):
        a = _const_positions(e.body, env)
        b = _const_positions(e.orelse, env)
        if a is None or b is None:
            return None
        return a | b
    return None


def _collect_jits(ctx: FileCtx):
    donated: Dict[str, Set[int]] = {}
    static: Dict[str, Set[int]] = {}
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)):
            continue
        env: Dict[str, ast.AST] = {}
        body = fn.body if not isinstance(fn, ast.Module) else fn.body
        for n in _own_walk(fn) if not isinstance(fn, ast.Module) else body:
            if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                    and isinstance(n.targets[0], ast.Name):
                env[n.targets[0].id] = n.value
        for n in (_own_walk(fn) if not isinstance(fn, ast.Module)
                  else ast.walk(ctx.tree)):
            if not (isinstance(n, ast.Assign)
                    and isinstance(n.value, ast.Call)):
                continue
            if ctx.resolve(_dotted(n.value.func)) != "jax.jit":
                continue
            tgt = None
            for t in n.targets:
                tgt = _dotted(t) or tgt
            if not tgt:
                continue
            for kw in n.value.keywords:
                pos = _const_positions(kw.value, env)
                if kw.arg == "donate_argnums" and pos:
                    donated[tgt] = pos
                elif kw.arg == "static_argnums" and pos:
                    static[tgt] = pos
    return donated, static


def rule_donate_and_retrace(ctx: FileCtx) -> None:
    donated, static = _collect_jits(ctx)

    # use-after-donate: per function, linear execution-order scan
    if donated:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            pending: Dict[str, int] = {}
            for ev in _linear(ctx, fn.body, donated):
                kind = ev[0]
                if kind == "read" and ev[1] in pending:
                    key, node = ev[1], ev[2]
                    ctx.add("use-after-donate", node.lineno,
                            node.col_offset,
                            f"`{key}` was donated to the jitted call at "
                            f"line {pending[key]} and is read before being "
                            f"rebound — donated buffers are invalidated by "
                            f"XLA and may alias freed memory")
                    del pending[key]
                elif kind == "write":
                    pending.pop(ev[1], None)
                elif kind == "donate":
                    for key in ev[1]:
                        pending[key] = ev[2].lineno

    # retrace-hazard (a): unhashable / per-call values at static positions
    for n in ast.walk(ctx.tree):
        if not isinstance(n, ast.Call):
            continue
        fd = _dotted(n.func)
        if fd in static:
            for pos in sorted(static[fd]):
                if pos >= len(n.args):
                    continue
                a = n.args[pos]
                bad = None
                if isinstance(a, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                  ast.SetComp, ast.DictComp,
                                  ast.GeneratorExp)):
                    bad = "an unhashable container literal"
                elif isinstance(a, ast.JoinedStr):
                    bad = "an f-string that varies per call"
                elif isinstance(a, ast.Call) and isinstance(a.func, ast.Name) \
                        and a.func.id in ("list", "dict", "set"):
                    bad = "a freshly-constructed container"
                if bad:
                    ctx.add("retrace-hazard", a.lineno, a.col_offset,
                            f"static arg {pos} of `{fd}` is {bad} — every "
                            f"call retraces (static args are compared by "
                            f"hash/equality)")
        # retrace-hazard (b): jax.jit inside a loop
        if ctx.resolve(fd) == "jax.jit":
            p = ctx.parents.get(id(n))
            while p is not None and not isinstance(
                    p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
                if isinstance(p, (ast.For, ast.While, ast.AsyncFor)):
                    ctx.add("retrace-hazard", n.lineno, n.col_offset,
                            "jax.jit() called inside a loop — builds a new "
                            "traced callable (and cache entry) every "
                            "iteration; hoist it out")
                    break
                p = ctx.parents.get(id(p))


# ---------------------------------------------------------------------------
# rule: blocking-in-async
# ---------------------------------------------------------------------------
def rule_blocking_in_async(ctx: FileCtx) -> None:
    queue_objs: Set[str] = set()
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call) \
                and ctx.resolve(_dotted(n.value.func)) == "queue.Queue":
            for t in n.targets:
                queue_objs.update(_write_targets(t))

    def in_executor(node: ast.AST) -> bool:
        p = ctx.parents.get(id(node))
        while p is not None and not isinstance(p, ast.AsyncFunctionDef):
            if isinstance(p, ast.Call):
                fa = p.func
                name = fa.attr if isinstance(fa, ast.Attribute) else \
                    getattr(fa, "id", "")
                if name in ("run_in_executor", "to_thread"):
                    return True
            p = ctx.parents.get(id(p))
        return False

    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        for n in _own_walk(fn):
            if not isinstance(n, ast.Call):
                continue
            rd = ctx.resolve(_dotted(n.func)) or ""
            if rd == "time.sleep":
                ctx.add("blocking-in-async", n.lineno, n.col_offset,
                        f"time.sleep() blocks the event loop in coroutine "
                        f"`{fn.name}` — use `await asyncio.sleep(...)`")
                continue
            if not isinstance(n.func, ast.Attribute):
                continue
            base = _dotted(n.func.value)
            attr = n.func.attr
            if attr in ("get", "put") and base in queue_objs \
                    and not in_executor(n):
                ctx.add("blocking-in-async", n.lineno, n.col_offset,
                        f"blocking queue.Queue.{attr}() on `{base}` in "
                        f"coroutine `{fn.name}` — use asyncio.Queue or an "
                        f"executor")
            elif attr in ("run_iteration", "step", "steps") and base \
                    and re.search(r"(agent|engine)", base.split(".")[-1]) \
                    and not in_executor(n):
                ctx.add("blocking-in-async", n.lineno, n.col_offset,
                        f"synchronous `{base}.{attr}()` in coroutine "
                        f"`{fn.name}` blocks the event loop for a full "
                        f"engine round — offload via run_in_executor or "
                        f"keep rounds bounded")


# ---------------------------------------------------------------------------
# rule: pallas-traced-branch
# ---------------------------------------------------------------------------
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _kernel_functions(ctx: FileCtx) -> List[ast.FunctionDef]:
    names: Set[str] = set()
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.Call):
            rd = ctx.resolve(_dotted(n.func)) or ""
            if rd.endswith("pallas_call") and n.args:
                a = n.args[0]
                if isinstance(a, ast.Name):
                    names.add(a.id)
                elif isinstance(a, ast.Call) and a.args \
                        and isinstance(a.args[0], ast.Name):
                    names.add(a.args[0].id)  # functools.partial(kernel, ..)
    out = []
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.FunctionDef) and (
                n.name in names or n.name.endswith("_kernel")
                or n.name == "kernel"):
            out.append(n)
    return out


def _expr_tainted(ctx: FileCtx, e: ast.AST, tainted: Set[str]) -> bool:
    for n in ast.walk(e):
        if isinstance(n, ast.Name) and n.id in tainted:
            p = ctx.parents.get(id(n))
            # X.shape / X.ndim / X.dtype are static even on traced X
            if isinstance(p, ast.Attribute) and p.attr in _STATIC_ATTRS:
                continue
            return True
        if isinstance(n, ast.Call):
            rd = ctx.resolve(_dotted(n.func)) or ""
            if rd.endswith("program_id"):
                return True
    return False


def rule_pallas_traced_branch(ctx: FileCtx) -> None:
    if f"kernels{os.sep}" not in ctx.rel and "kernels/" not in ctx.rel:
        return
    for fn in _kernel_functions(ctx):
        tainted = {a.arg for a in fn.args.args + fn.args.kwonlyargs
                   if a.arg != "self"}

        def scan(stmts: Sequence[ast.stmt]) -> None:
            for s in stmts:
                if isinstance(s, ast.Assign):
                    is_t = _expr_tainted(ctx, s.value, tainted)
                    for t in s.targets:
                        for k in _write_targets(t):
                            if "." in k:
                                continue
                            (tainted.add if is_t else tainted.discard)(k)
                elif isinstance(s, ast.If):
                    if _expr_tainted(ctx, s.test, tainted):
                        ctx.add("pallas-traced-branch", s.lineno,
                                s.col_offset,
                                f"Python `if` on a traced value inside "
                                f"Pallas kernel `{fn.name}` — tracing "
                                f"picks ONE branch at compile time; use "
                                f"jnp.where, pl.when, or lax.cond")
                    scan(s.body)
                    scan(s.orelse)
                elif isinstance(s, (ast.For, ast.While)):
                    scan(s.body)
                    scan(s.orelse)
                elif isinstance(s, ast.With):
                    scan(s.body)

        scan(fn.body)


# ---------------------------------------------------------------------------
# rule: unguarded-div
# ---------------------------------------------------------------------------
def _mentions(e: ast.AST, keys: Set[str]) -> bool:
    for n in ast.walk(e):
        if isinstance(n, (ast.Name, ast.Attribute)):
            d = _dotted(n)
            if d in keys:
                return True
    return False


def _terminal(stmt_list: Sequence[ast.stmt]) -> bool:
    return bool(stmt_list) and isinstance(
        stmt_list[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def rule_unguarded_div(ctx: FileCtx) -> None:
    funcs = [n for n in ast.walk(ctx.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        for n in _own_walk(fn):
            if not (isinstance(n, ast.BinOp)
                    and isinstance(n.op, (ast.Div, ast.FloorDiv))):
                continue
            denom = n.right
            keys: Set[str] = set()
            label = None
            if isinstance(denom, (ast.Name, ast.Attribute)):
                d = _dotted(denom)
                if not d:
                    continue
                last = d.split(".")[-1]
                if not _COUNTERISH.search(last):
                    continue
                label = d
                keys = {d}
            elif isinstance(denom, ast.Call) \
                    and isinstance(denom.func, ast.Name) \
                    and denom.func.id == "len" and denom.args:
                inner = _dotted(denom.args[0])
                if not inner:
                    continue
                label = f"len({inner})"
                keys = {inner, label}
            else:
                continue  # max()/or-guards/arithmetic denominators are safe
            if _div_guarded(ctx, fn, n, keys):
                continue
            ctx.add("unguarded-div", n.lineno, n.col_offset,
                    f"division by possibly-zero `{label}` — guard with "
                    f"`max({label}, 1)`, `... if {label} else ...`, or an "
                    f"early return (zero-request / all-rejected runs hit "
                    f"this)")


def _div_guarded(ctx: FileCtx, fn: ast.AST, div: ast.BinOp,
                 keys: Set[str]) -> bool:
    # ancestor if/while/ternary whose test mentions the denominator
    p = ctx.parents.get(id(div))
    while p is not None and p is not fn:
        if isinstance(p, (ast.If, ast.While, ast.IfExp)) \
                and _mentions(p.test, keys):
            return True
        if isinstance(p, ast.Assert) and _mentions(p.test, keys):
            return True
        p = ctx.parents.get(id(p))
    # earlier early-return guard or assert in the same function
    for s in _own_walk(fn):
        if getattr(s, "lineno", 10**9) >= div.lineno:
            continue
        if isinstance(s, ast.If) and _mentions(s.test, keys) \
                and _terminal(s.body):
            return True
        if isinstance(s, ast.Assert) and _mentions(s.test, keys):
            return True
        if isinstance(s, ast.Assign):
            # denom rebound through a guard: d = max(d, 1) / d = x or 1
            tgts = {k for t in s.targets for k in _write_targets(t)}
            if tgts & keys and (isinstance(s.value, ast.BoolOp) or (
                    isinstance(s.value, ast.Call)
                    and isinstance(s.value.func, ast.Name)
                    and s.value.func.id in ("max", "min"))):
                return True
    return False


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
_ALL_RULES = (rule_host_sync, rule_donate_and_retrace,
              rule_blocking_in_async, rule_pallas_traced_branch,
              rule_unguarded_div)


def lint_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    try:
        ctx = FileCtx(path, rel or path, source)
    except SyntaxError as e:
        return [Finding("syntax-error", rel or path, e.lineno or 0, 0,
                        str(e))]
    for rule in _ALL_RULES:
        rule(ctx)
    ctx.findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return ctx.findings


def iter_py(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py(paths):
        findings.extend(lint_file(path, os.path.relpath(path)))
    return findings


def _self_test(paths: Sequence[str]) -> int:
    """Copy the tree, inject a known hot-path violation, assert nonzero."""
    import shutil
    import tempfile
    engine = None
    for path in iter_py(paths):
        if path.replace(os.sep, "/").endswith("serving/engine.py"):
            engine = path
            break
    if engine is None:
        print("qlint self-test: no serving/engine.py under target",
              file=sys.stderr)
        return 1
    with tempfile.TemporaryDirectory() as tmp:
        dst = os.path.join(tmp, "engine.py")
        shutil.copy(engine, dst)
        with open(dst, encoding="utf-8") as fh:
            lines = fh.readlines()
        for i, line in enumerate(lines):
            m = re.match(r"(\s*)def _decode_round\(", line)
            if m:
                indent = m.group(1) + "    "
                lines.insert(
                    i + 1, f"{indent}_injected = jax.device_get("
                           f"self.lengths)\n")
                break
        else:
            print("qlint self-test: _decode_round not found",
                  file=sys.stderr)
            return 1
        with open(dst, "w", encoding="utf-8") as fh:
            fh.writelines(lines)
        hits = [f for f in lint_file(dst, "self-test/engine.py")
                if f.rule == "host-sync-in-hot-path" and not f.waived
                and "_injected" not in f.message and f.line > 0
                and "device_get" in f.message]
    if hits:
        print(f"qlint self-test OK: injected device_get in _decode_round "
              f"was flagged ({hits[0].render()})")
        return 0
    print("qlint self-test FAILED: injected hot-path sync was NOT flagged",
          file=sys.stderr)
    return 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX/Pallas-aware static analysis for the serving "
                    "stack")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report (incl. waived/baselined) "
                         "as JSON")
    ap.add_argument("--baseline", metavar="PATH",
                    default="qlint_baseline.json",
                    help="fingerprint baseline; gate is zero NEW findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current unwaived findings to the baseline "
                         "and exit 0")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived and baselined findings")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--self-test", action="store_true",
                    help="inject a known violation and assert a nonzero "
                         "gate")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule:24s} {desc}")
        return 0
    if args.self_test:
        return _self_test(args.paths or ["src"])

    findings = lint_paths(args.paths or ["src"])

    baseline: Set[str] = set()
    if args.baseline and os.path.exists(args.baseline) \
            and not args.write_baseline:
        with open(args.baseline, encoding="utf-8") as fh:
            baseline = set(json.load(fh).get("fingerprints", []))
    for f in findings:
        if not f.waived and f.fingerprint in baseline:
            f.baselined = True

    active = [f for f in findings if not f.waived and not f.baselined]

    if args.write_baseline:
        payload = {"fingerprints": sorted({f.fingerprint for f in active})}
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"wrote {len(payload['fingerprints'])} fingerprint(s) to "
              f"{args.baseline}")
        return 0

    shown = findings if args.show_waived else active
    for f in shown:
        print(f.render())
    n_waived = sum(f.waived for f in findings)
    n_base = sum(f.baselined for f in findings)
    print(f"qlint: {len(active)} finding(s) "
          f"({n_waived} waived, {n_base} baselined)")

    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump({
                "findings": [f.to_json() for f in findings],
                "summary": {"active": len(active), "waived": n_waived,
                            "baselined": n_base},
            }, fh, indent=2)
            fh.write("\n")

    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
