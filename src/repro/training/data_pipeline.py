"""Synthetic LM data pipeline: deterministic token streams + batching.

For the end-to-end train driver (examples/train_tiny.py): a mixture of a
Zipf unigram stream and copy/repeat structure so the loss has learnable
signal (pure-uniform tokens would plateau at ln V immediately)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticLMDataset:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, structure: float = 0.7):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.structure = structure
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _sample_seq(self) -> np.ndarray:
        n = self.seq + 1
        toks = self.rng.choice(self.vocab, size=n, p=self.unigram)
        # inject copy structure: random spans repeat earlier content
        i = 1
        while i < n:
            if self.rng.random() < self.structure and i > 8:
                span = int(self.rng.integers(4, 16))
                start = int(self.rng.integers(0, i - span)) if i - span > 0 else 0
                span = min(span, n - i, i - start)
                if span > 0:
                    toks[i:i + span] = toks[start:start + span]
                    i += span
                    continue
            i += 1
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            batch = np.stack([self._sample_seq() for _ in range(self.batch)])
            yield {"tokens": batch.astype(np.int32)}
