"""Training step factory: loss + AdamW + (optional) microbatch gradient
accumulation, built per architecture from the model factory."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_factory import Model
from repro.training.optimizer import AdamW, AdamWState, global_norm


def make_train_step(model: Model, opt: AdamW, *, microbatches: int = 1,
                    remat: bool = True):
    """Returns ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)``.

    With ``microbatches > 1`` the global batch is split on axis 0 and
    gradients are accumulated in a ``lax.scan`` — the standard memory-vs-
    time knob for the big dense archs (see EXPERIMENTS §Perf).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    def single(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads))
        return params, opt_state, metrics

    if microbatches == 1:
        return single

    def accumulated(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss = loss_sum / microbatches
        updates, new_opt_state = opt.update(grads, opt_state, params)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "ce": loss, "aux": jnp.float32(0.0)}
        return new_params, new_opt_state, metrics

    return accumulated
