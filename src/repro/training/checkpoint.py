"""Minimal npz + JSON-manifest checkpointing for params/opt state."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "metadata": metadata or {}}, f)


def restore_checkpoint(path: str, params_template) -> Tuple[Any, int]:
    """Restores into the treedef of ``params_template``."""
    data = np.load(os.path.join(path, "params.npz"))
    flat_template = _flatten(params_template)
    assert set(data.files) == set(flat_template), "checkpoint/template mismatch"
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params_template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_with_path]
    restored = [data[k] for k in keys]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["step"]
