"""AdamW + schedules, from scratch (no optax in this container).

Functional interface mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; the train step
applies ``params + updates``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return self.learning_rate

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(m, v, p):
            # compute in f32, store in the param dtype — otherwise the
            # strong-f32 bias correction silently promotes params to f32
            # (2x memory + broken donation aliasing; EXPERIMENTS §Perf H1)
            mhat = m.astype(jnp.float32) / b1c
            vhat = v.astype(jnp.float32) / b2c
            return (-lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                           + self.weight_decay * p.astype(jnp.float32))
                    ).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
