from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.data_pipeline import SyntheticLMDataset
from repro.training.optimizer import AdamW, cosine_schedule, global_norm
from repro.training.train_step import make_train_step

__all__ = ["AdamW", "cosine_schedule", "global_norm", "make_train_step",
           "save_checkpoint", "restore_checkpoint", "SyntheticLMDataset"]
