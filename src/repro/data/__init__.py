from repro.data.sharegpt_synth import MEGA_PROMPT, SHAREGPT, sample_lengths
from repro.data.workload import WorkloadSpec, generate, workload_a, workload_b, workload_c

__all__ = ["SHAREGPT", "MEGA_PROMPT", "sample_lengths", "WorkloadSpec",
           "generate", "workload_a", "workload_b", "workload_c"]
