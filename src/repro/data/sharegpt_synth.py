"""Synthetic ShareGPT-like token-length distributions (paper Fig. 8).

No network access in this container, so we fit the published shape: both
input and output token counts in ShareGPT are heavy-tailed with medians
around 30–60 (input) and 150–250 (output), truncated at the 2k context.
Lognormal fits reproduce the Fig. 8 histograms closely enough for the
scheduling experiments (the paper's results depend on the mean/variance
through the RWT estimator, Eq. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDistribution:
    mu_log_input: float = 3.8      # median ≈ 45 input tokens
    sigma_log_input: float = 1.1
    mu_log_output: float = 5.1     # median ≈ 164 output tokens
    sigma_log_output: float = 0.9
    max_tokens: int = 2048

    def sample(self, rng: np.random.Generator, n: int) -> Tuple[np.ndarray, np.ndarray]:
        ins = np.clip(rng.lognormal(self.mu_log_input, self.sigma_log_input, n),
                      1, self.max_tokens).astype(int)
        outs = np.clip(rng.lognormal(self.mu_log_output, self.sigma_log_output, n),
                       1, self.max_tokens).astype(int)
        return ins, outs


SHAREGPT = TokenDistribution()

# W_C "mega prompts": total input+output in the 3k–4k range (§8 Workloads)
MEGA_PROMPT = TokenDistribution(mu_log_input=7.6, sigma_log_input=0.12,
                                mu_log_output=7.0, sigma_log_output=0.15,
                                max_tokens=4096)


def sample_lengths(rng: np.random.Generator, n: int,
                   mega_fraction: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
    ins, outs = SHAREGPT.sample(rng, n)
    if mega_fraction > 0:
        m = rng.random(n) < mega_fraction
        mi, mo = MEGA_PROMPT.sample(rng, int(m.sum()))
        # clip total to the 3k-4k band
        total = mi + mo
        # a zero-length sample would make scale inf/NaN and astype(int)
        # then emits garbage lengths downstream
        scale = np.clip(total, 3000, 4000) / np.maximum(total, 1)
        ins[m] = (mi * scale).astype(int)
        outs[m] = (mo * scale).astype(int)
    return ins, outs
