"""Workload generators (paper §8): Poisson arrivals over ShareGPT-like
token distributions, W_A / W_B / W_C scenario builders, and multi-turn
**sessions** (FAIRSERVE's ``Interaction``/``next_request`` shape) whose
follow-up requests carry the previous turns' tokens as a prompt prefix —
the traffic the prefix index and ``fork_slot`` actually serve.

SLO classes (p99 TTFT): Interactive 20 s, Batch-1 60 s, Batch-2 3600 s.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request, make_request
from repro.data.sharegpt_synth import sample_lengths


@dataclasses.dataclass
class WorkloadSpec:
    name: str
    n_requests: int = 3500
    seed: int = 0
    # class mix: (slo_class, model, fraction)
    mix: Sequence = ()
    arrival_rate: float = 50.0        # requests / second (Poisson)
    burstiness_cv: float = 1.0        # 1.0 = Poisson; >1 via gamma interarrivals
    mega_fraction: float = 0.0


def _arrivals(rng: np.random.Generator, n: int, rate: float, cv: float) -> np.ndarray:
    if cv <= 1.0:
        gaps = rng.exponential(1.0 / rate, n)
    else:  # gamma-distributed interarrivals with CV>1 => bursty
        shape = 1.0 / (cv * cv)
        gaps = rng.gamma(shape, 1.0 / (rate * shape), n)
    return np.cumsum(gaps)


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_requests
    ins, outs = sample_lengths(rng, n, spec.mega_fraction)
    arrivals = _arrivals(rng, n, spec.arrival_rate, spec.burstiness_cv)
    fractions = np.array([f for (_, _, f) in spec.mix], float)
    fractions = fractions / fractions.sum()
    classes = rng.choice(len(spec.mix), size=n, p=fractions)
    out: List[Request] = []
    for i in range(n):
        slo_class, model, _ = spec.mix[classes[i]]
        prompt = rng.integers(0, 32000, size=int(ins[i])).tolist()
        r = make_request(prompt, model, slo_class,
                         arrival_time=float(arrivals[i]),
                         max_new_tokens=int(outs[i]))
        r.true_output_tokens = int(outs[i])  # ground truth for the simulator
        out.append(r)
    out.sort(key=lambda r: r.arrival_time)
    return out


# ---------------------------------------------------------------------------
# multi-turn sessions (FAIRSERVE Interaction shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Session:
    """A multi-turn interaction: each turn's request prompt is the FULL
    conversation so far (previous prompts + generated outputs) plus that
    turn's fresh tokens, so a follow-up re-entering the queue is a
    shared-prefix hit against the previous turn's published prompt blocks.

    Lifecycle mirrors FAIRSERVE's ``Interaction``: ``next_request(now)``
    materializes the next turn (None when the session is done), the caller
    serves it, then ``complete_turn(req)`` folds prompt+output into the
    history before the next call.
    """
    session_id: int
    model: str
    slo_class: str
    turn_prompts: List[List[int]]          # fresh tokens per turn
    max_new_tokens: int = 16
    think_time_s: float = 0.0              # client-side gap between turns
    arrival_time: float = 0.0              # first turn's arrival
    slo_s: Optional[float] = None          # per-turn TTFT SLO override
    history: List[int] = dataclasses.field(default_factory=list)
    turn: int = 0
    requests: List[Request] = dataclasses.field(default_factory=list)

    def done(self) -> bool:
        return self.turn >= len(self.turn_prompts)

    def next_request(self, now: float) -> Optional[Request]:
        if self.done():
            return None
        prompt = list(self.history) + list(self.turn_prompts[self.turn])
        r = make_request(prompt, self.model, self.slo_class,
                         arrival_time=max(now, self.arrival_time),
                         max_new_tokens=self.max_new_tokens)
        if self.slo_s is not None:
            r.slo = self.slo_s
        r.session_id = self.session_id
        r.turn = self.turn
        self.turn += 1
        self.requests.append(r)
        return r

    def complete_turn(self, req: Request) -> None:
        """Fold a served turn into the conversation history (the next
        turn's prompt prefix)."""
        self.history = list(req.prompt_tokens) + list(req.output_tokens)


@dataclasses.dataclass
class SessionSpec:
    n_sessions: int = 8
    turns: int = 3
    seed: int = 0
    model: str = "vicuna-13b"
    slo_class: str = "interactive"
    arrival_rate: float = 2.0              # sessions / second (Poisson)
    think_time_s: float = 0.0
    prompt_tokens: Tuple[int, int] = (8, 24)   # fresh tokens per turn (lo, hi)
    max_new_tokens: int = 16
    vocab: int = 32000


def generate_sessions(spec: SessionSpec) -> List[Session]:
    """Poisson session arrivals; each session's per-turn fresh token runs
    are pre-sampled so the workload is reproducible under any serving
    order (only the generated outputs — deterministic under greedy
    decoding — vary the history)."""
    rng = np.random.default_rng(spec.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / spec.arrival_rate,
                                         spec.n_sessions))
    lo, hi = spec.prompt_tokens
    out: List[Session] = []
    for s in range(spec.n_sessions):
        prompts = [rng.integers(0, spec.vocab,
                                size=int(rng.integers(lo, hi + 1))).tolist()
                   for _ in range(spec.turns)]
        out.append(Session(session_id=s, model=spec.model,
                           slo_class=spec.slo_class, turn_prompts=prompts,
                           max_new_tokens=spec.max_new_tokens,
                           think_time_s=spec.think_time_s,
                           arrival_time=float(arrivals[s])))
    return out


# ---------------------------------------------------------------------------
# paper scenarios (§8 Workloads)
# ---------------------------------------------------------------------------

def workload_a(arrival_rate: float, n_requests: int = 3500, seed: int = 0,
               model: str = "vicuna-13b") -> List[Request]:
    """W_A: single-model interactive + batch."""
    return generate(WorkloadSpec(
        name="W_A", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mix=[("interactive", model, 0.4),
             ("batch1", model, 0.3),
             ("batch2", model, 0.3)]))


def workload_b(arrival_rate: float, n_requests: int = 3500, seed: int = 0) -> List[Request]:
    """W_B: multi-model batch.  Batch-1 on two models (mistral-7b-ft,
    llama-70b-ft1); Batch-2 on three (vicuna-13b-ft, llama-70b-ft2, ...)."""
    return generate(WorkloadSpec(
        name="W_B", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mix=[("batch1", "mistral-7b-ft", 0.25),
             ("batch1", "llama-70b-ft1", 0.25),
             ("batch2", "vicuna-13b-ft", 0.20),
             ("batch2", "llama-70b-ft2", 0.15),
             ("batch2", "vicuna-13b-ft2", 0.15)]))


def workload_c(arrival_rate: float, n_requests: int = 3500, seed: int = 0,
               mega_fraction: float = 0.1, model: str = "vicuna-13b") -> List[Request]:
    """W_C: W_A plus mega prompts (3k–4k total tokens)."""
    return generate(WorkloadSpec(
        name="W_C", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mega_fraction=mega_fraction,
        mix=[("interactive", model, 0.4),
             ("batch1", model, 0.3),
             ("batch2", model, 0.3)]))
