"""Workload generators (paper §8): Poisson arrivals over ShareGPT-like
token distributions, W_A / W_B / W_C scenario builders.

SLO classes (p99 TTFT): Interactive 20 s, Batch-1 60 s, Batch-2 3600 s.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request, make_request
from repro.data.sharegpt_synth import sample_lengths


@dataclasses.dataclass
class WorkloadSpec:
    name: str
    n_requests: int = 3500
    seed: int = 0
    # class mix: (slo_class, model, fraction)
    mix: Sequence = ()
    arrival_rate: float = 50.0        # requests / second (Poisson)
    burstiness_cv: float = 1.0        # 1.0 = Poisson; >1 via gamma interarrivals
    mega_fraction: float = 0.0


def _arrivals(rng: np.random.Generator, n: int, rate: float, cv: float) -> np.ndarray:
    if cv <= 1.0:
        gaps = rng.exponential(1.0 / rate, n)
    else:  # gamma-distributed interarrivals with CV>1 => bursty
        shape = 1.0 / (cv * cv)
        gaps = rng.gamma(shape, 1.0 / (rate * shape), n)
    return np.cumsum(gaps)


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_requests
    ins, outs = sample_lengths(rng, n, spec.mega_fraction)
    arrivals = _arrivals(rng, n, spec.arrival_rate, spec.burstiness_cv)
    fractions = np.array([f for (_, _, f) in spec.mix], float)
    fractions = fractions / fractions.sum()
    classes = rng.choice(len(spec.mix), size=n, p=fractions)
    out: List[Request] = []
    for i in range(n):
        slo_class, model, _ = spec.mix[classes[i]]
        prompt = rng.integers(0, 32000, size=int(ins[i])).tolist()
        r = make_request(prompt, model, slo_class,
                         arrival_time=float(arrivals[i]),
                         max_new_tokens=int(outs[i]))
        r.true_output_tokens = int(outs[i])  # ground truth for the simulator
        out.append(r)
    out.sort(key=lambda r: r.arrival_time)
    return out


# ---------------------------------------------------------------------------
# paper scenarios (§8 Workloads)
# ---------------------------------------------------------------------------

def workload_a(arrival_rate: float, n_requests: int = 3500, seed: int = 0,
               model: str = "vicuna-13b") -> List[Request]:
    """W_A: single-model interactive + batch."""
    return generate(WorkloadSpec(
        name="W_A", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mix=[("interactive", model, 0.4),
             ("batch1", model, 0.3),
             ("batch2", model, 0.3)]))


def workload_b(arrival_rate: float, n_requests: int = 3500, seed: int = 0) -> List[Request]:
    """W_B: multi-model batch.  Batch-1 on two models (mistral-7b-ft,
    llama-70b-ft1); Batch-2 on three (vicuna-13b-ft, llama-70b-ft2, ...)."""
    return generate(WorkloadSpec(
        name="W_B", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mix=[("batch1", "mistral-7b-ft", 0.25),
             ("batch1", "llama-70b-ft1", 0.25),
             ("batch2", "vicuna-13b-ft", 0.20),
             ("batch2", "llama-70b-ft2", 0.15),
             ("batch2", "vicuna-13b-ft2", 0.15)]))


def workload_c(arrival_rate: float, n_requests: int = 3500, seed: int = 0,
               mega_fraction: float = 0.1, model: str = "vicuna-13b") -> List[Request]:
    """W_C: W_A plus mega prompts (3k–4k total tokens)."""
    return generate(WorkloadSpec(
        name="W_C", n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        mega_fraction=mega_fraction,
        mix=[("interactive", model, 0.4),
             ("batch1", model, 0.3),
             ("batch2", model, 0.3)]))
