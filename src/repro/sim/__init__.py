from repro.sim.profiles import DEVICE_PROFILES, calibrate_from_engine, profiles_for
from repro.sim.simulator import ClusterSimulator, SimInstance

__all__ = ["ClusterSimulator", "SimInstance", "DEVICE_PROFILES",
           "calibrate_from_engine", "profiles_for"]
