"""Discrete-event cluster simulator for paper-scale experiments.

Token-granular continuous batching: every instance iteration generates one
token for each running sequence and lasts ``d`` seconds (+ prefill cost
``P`` on iterations that admitted new work, + swap cost ``S`` when the
agent switches models).  KV memory is tracked per token against the
device's ``token_capacity``; overflow preempts the newest sequence
(vLLM semantics).  Eviction and swap follow the same LSO rules as the real
engine's ``QLMAgent`` — the simulator and engine share the QLM core
(groups / virtual queues / RWT / global scheduler) verbatim.

Execution semantics honor ``PolicyTraits``:
  * ``continuous_batching=False`` (SHEPHERD): admissions only into an empty
    batch; the batch runs to completion (fixed batching);
  * ``uses_eviction`` / ``plans_swaps`` gate the corresponding LSOs.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.global_scheduler import InstanceInfo
from repro.core.policies import PolicyTraits, make_policy
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import Request
from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue


@dataclasses.dataclass
class SimSeq:
    req: Request
    kv_tokens: int          # prompt + generated so far
    remaining: int          # output tokens still to generate (ground truth)
    prefill_remaining: int = 0  # prompt tokens not yet prefilled (chunked mode)


@dataclasses.dataclass
class SimStats:
    iterations: int = 0
    prefill_rounds: int = 0
    swaps: int = 0
    evictions: int = 0
    preemptions: int = 0
    busy_time: float = 0.0
    swap_time: float = 0.0
    tokens: int = 0


class SimInstance:
    def __init__(self, instance_id: int,
                 hw_by_model: Dict[str, HardwareProfile],
                 traits: PolicyTraits,
                 max_batch_requests: int = 256):
        self.id = instance_id
        self.hw_by_model = hw_by_model
        self.traits = traits
        self.max_batch = max_batch_requests
        self.vq = VirtualQueue(instance_id)
        self.loaded_model: Optional[str] = None
        self.running: List[SimSeq] = []
        self.kv_used = 0
        self.stats = SimStats()
        self.busy_until = 0.0
        self.scheduled = False  # an 'iter' event is in flight
        self._last_head: Optional[int] = None  # eviction fires on head CHANGE (§5)

    # ------------------------------------------------------------------
    def info(self) -> InstanceInfo:
        return InstanceInfo(instance_id=self.id, hw_by_model=self.hw_by_model,
                            current_model=self.loaded_model,
                            virtual_queue=self.vq)

    def hw(self) -> Optional[HardwareProfile]:
        if self.loaded_model is None:
            return None
        return self.hw_by_model[self.loaded_model]

    def capacity(self) -> int:
        hw = self.hw()
        return hw.token_capacity if hw else 0

    # ------------------------------------------------------------------
    def _evict_seq(self, seq: SimSeq, *, preempted: bool = False) -> None:
        """Back into its group's pending set; progress (generated) kept —
        the KV snapshot lives in host memory (eviction LSO).  Mid-prefill
        chunk progress is kept too, mirroring the engine's
        ``snapshot["prefill_pos"]`` resume (no recompute)."""
        self.running.remove(seq)
        self.kv_used -= seq.kv_tokens
        seq.req._prefill_done = seq.req.prompt_len - seq.prefill_remaining
        seq.req._in_flight = False
        seq.req.n_evictions += 1
        if preempted:
            self.stats.preemptions += 1
        else:
            self.stats.evictions += 1

    def _agent_sync(self, now: float) -> float:
        """LSO actuation (mirrors core.lso.QLMAgent.sync). Returns extra
        time consumed (model swap)."""
        head = self.vq.head_group()
        if head is None:
            return 0.0
        extra = 0.0
        if head.model != self.loaded_model:
            if self.loaded_model is None:
                # cold instance: load the model (always allowed)
                self.loaded_model = head.model
                extra += self.hw_by_model[head.model].swap_time
                self.stats.swaps += 1
            elif self.traits.plans_swaps or not self.running:
                # swap LSO: flush + load (baselines only swap when idle —
                # they don't plan swaps, matching "swap on demand")
                for seq in list(self.running):
                    self._evict_seq(seq)
                self.loaded_model = head.model
                extra += self.hw_by_model[head.model].swap_time
                self.stats.swaps += 1
        head_changed = head.group_id != self._last_head
        self._last_head = head.group_id
        if self.traits.uses_eviction and head.model == self.loaded_model \
                and head_changed:
            # §5: eviction fires when the global scheduler CHANGES the head
            # group (an RWT-detected violation put a tighter group first);
            # evicting on mere blockage thrashes an underloaded system.
            first = head.next_pending()
            if first is not None:
                need = first.prompt_len + first.generated + 1
                blocked = (self.kv_used + need > self.capacity()
                           or len(self.running) >= self.max_batch)
                if blocked:
                    for seq in sorted(
                            (s for s in self.running
                             if s.req.group_id != head.group_id),
                            key=lambda s: -s.req.slo):  # loosest SLO first
                        self._evict_seq(seq)
                        if self.kv_used + need <= self.capacity() and \
                                len(self.running) < self.max_batch:
                            break
        self.stats.swap_time += extra
        return extra

    def _admit(self, now: float) -> Tuple[int, int]:
        """Request pulling LSO: FCFS within the head group.
        Returns (n_admitted, prompt_tokens_admitted)."""
        if not self.traits.continuous_batching and self.running:
            return 0, 0  # fixed batching (SHEPHERD)
        admitted = 0
        prompt_tokens = 0
        while len(self.running) < self.max_batch:
            req = self.vq.next_request(self.loaded_model)
            if req is None:
                break
            fresh = req.generated == 0  # eviction resume restores KV, no prefill
            # shared-prefix cache hits (ground truth, like
            # true_output_tokens): the leading run neither occupies new KV
            # (it rides the shared chain) nor runs prefill compute.  Only
            # first admissions benefit; a resume restores its snapshot.
            shared = 0
            if fresh:
                shared = min(max(getattr(req, "prefix_shared_tokens", 0), 0),
                             max(req.prompt_len - 1, 0))
            need = req.prompt_len + req.generated + 1 - shared
            if self.kv_used + need > self.capacity():
                break
            req._in_flight = True
            rem = max((req.true_output_tokens or req.max_new_tokens) - req.generated, 1)
            pre = 0
            if fresh and self.traits.prefill_chunk_tokens:
                # mid-prefill evictions resume from their snapshot progress
                # (which already covers the shared run — don't double-count)
                done = max(getattr(req, "_prefill_done", 0), shared)
                pre = max(req.prompt_len - done, 0)
            self.running.append(SimSeq(req, kv_tokens=need - 1, remaining=rem,
                                       prefill_remaining=pre))
            self.kv_used += need - 1
            admitted += 1
            if fresh:
                prompt_tokens += req.prompt_len - shared
        return admitted, prompt_tokens

    def iteration(self, now: float) -> Tuple[float, List[Request]]:
        """Run one serve-loop quantum starting at ``now``.
        Returns (finish_time, completed_requests)."""
        extra = self._agent_sync(now)
        admitted, prompt_tokens = self._admit(now + extra)
        hw = self.hw()
        if hw is None or not self.running:
            self.busy_until = now + extra
            return self.busy_until, []
        # per-model quantum: the engine clamps its chunk to the model's
        # sliding window (engine._chunk_quantum); HardwareProfile carries
        # the window and owns the clamp (hw.chunk_quantum) so sim chunk
        # counts match the engine for SWA models
        chunk = self.traits.prefill_chunk_tokens
        if chunk:
            chunk = hw.chunk_quantum(chunk)
        dur = extra
        if chunk:
            # chunked prefill (mirrors the real engine's step()): every
            # mid-prefill sequence advances by at most ``chunk`` prompt
            # tokens this iteration, THEN decode runs for the sequences that
            # are prefill-complete — like the engine, a sequence finishing
            # its final chunk decodes in the same quantum.
            processed = 0
            for seq in self.running:
                if seq.prefill_remaining > 0:
                    n = min(chunk, seq.prefill_remaining)
                    seq.prefill_remaining -= n
                    processed += n
            if processed:
                dur += hw.prefill_time * (processed / 1024.0)
                self.stats.prefill_rounds += 1
            if any(s.prefill_remaining == 0 for s in self.running):
                # the engine's decode round is a no-op while every running
                # sequence is still mid-prefill — don't charge d for it.
                # Chunk-interleaved iterations dispatch single-step (the
                # engine's burst fallback), so no dispatch amortization.
                dur += hw.decode_seconds(1 if processed else None)
        else:
            # burst-amortized per-iteration cost: the engine fuses
            # decode_burst iterations per dispatch, so the per-dispatch
            # host overhead is charged once per burst, not once per token
            dur += hw.decode_seconds()
            if admitted:
                # lump accounting: prefill cost scales with admitted PROMPT
                # tokens (the paper's §6 observation: per-input-token cost
                # ≈ 100x below per-output-token cost; hw.prefill_time is per
                # 1k prompt tokens)
                dur += hw.prefill_time * (prompt_tokens / 1024.0)
                self.stats.prefill_rounds += 1
        end = now + dur
        completed: List[Request] = []
        for seq in list(self.running):
            if seq.prefill_remaining > 0:
                continue  # still prefilling: no decode token this iteration
            seq.kv_tokens += 1
            self.kv_used += 1
            seq.remaining -= 1
            seq.req.generated += 1
            self.stats.tokens += 1
            if seq.req.first_token_time is None:
                seq.req.first_token_time = end
            if seq.remaining <= 0:
                seq.req.completion_time = end
                seq.req._in_flight = False
                self.running.remove(seq)
                self.kv_used -= seq.kv_tokens
                completed.append(seq.req)
        # KV overflow: preempt newest (vLLM recompute/preempt semantics)
        while self.kv_used > self.capacity() and self.running:
            self._evict_seq(self.running[-1], preempted=True)
        self.stats.iterations += 1
        self.stats.busy_time += dur
        self.busy_until = end
        return end, completed

    def has_work(self) -> bool:
        return bool(self.running) or self.vq.pending_requests() > 0


# ---------------------------------------------------------------------------

class ClusterSimulator:
    def __init__(self, instance_profiles: Sequence[Dict[str, HardwareProfile]],
                 policy_name: str = "qlm", *, qlm_cfg: Optional[QLMConfig] = None,
                 max_batch_requests: int = 256, seed: int = 0,
                 traits_override: Optional[Dict] = None):
        self.policy = make_policy(policy_name)
        traits = self.policy.traits
        if traits_override:
            traits = dataclasses.replace(traits, **traits_override)
        if traits.prefill_chunk_tokens:
            # keep the RWT hardware model coherent with the execution model:
            # chunk-interleaved prefill changes both the iteration schedule
            # AND the estimator's prefill term (hw.prefill_seconds)
            instance_profiles = [
                {m: dataclasses.replace(
                    hw, prefill_chunk_tokens=traits.prefill_chunk_tokens)
                 for m, hw in prof.items()}
                for prof in instance_profiles]
        # SHEPHERD's waiting over-estimation: scale its view of drain times
        self.instances = [
            SimInstance(i, prof, traits, max_batch_requests)
            for i, prof in enumerate(instance_profiles)]
        self.traits = traits
        self.controller: Optional[QLMController] = None
        if traits.name == "qlm":
            self.controller = QLMController(
                [inst.info() for inst in self.instances],
                cfg=qlm_cfg, seed=seed)
            if not traits.reorders:  # fig11/14 ablation: pulling only
                self.controller.cfg = dataclasses.replace(
                    self.controller.cfg, reschedule_on_arrival=False)
        self._groups: List[RequestGroup] = []   # baseline-managed groups
        self.completed: List[Request] = []
        self.now = 0.0

    # ------------------------------------------------------------------
    def _infos(self) -> List[InstanceInfo]:
        return [inst.info() for inst in self.instances]

    def _on_arrival(self, req: Request) -> None:
        if self.controller is not None:
            # keep controller instance views fresh (loaded models change)
            self.controller.instances = self._infos()
            self.controller.submit(req, self.now)
            return
        # baselines: singleton group, incremental placement
        g = RequestGroup(model=req.model, slo=req.slo)
        g.add(req)
        self._groups.append(g)
        name = self.traits.name
        if name == "shepherd":
            models = sorted({x.model for x in self._groups})
            candidates = self._shepherd_subset(req.model, models)
        else:
            candidates = self.instances
        inst = min(candidates, key=lambda i: i.vq.pending_requests())
        if name == "vllm":
            inst.vq.groups.append(g)
        else:  # edf & shepherd: deadline-sorted insert
            idx = 0
            q = inst.vq.groups
            while idx < len(q) and q[idx].earliest_deadline() <= g.earliest_deadline():
                idx += 1
            q.insert(idx, g)

    def _shepherd_subset(self, model: str, models: List[str]) -> List[SimInstance]:
        n_inst = len(self.instances)
        i = models.index(model)
        lo = (i * n_inst) // len(models)  # qlint: disable=unguarded-div -- models contains `model` (index above raised otherwise), so non-empty
        hi = max(lo + 1, ((i + 1) * n_inst) // len(models))  # qlint: disable=unguarded-div -- same: models proven non-empty by .index
        return self.instances[lo:hi]

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *,
            max_sim_time: float = 1e7) -> Dict[str, float]:
        counter = itertools.count()
        heap: List[Tuple[float, int, str, object]] = []
        for r in requests:
            heapq.heappush(heap, (r.arrival_time, next(counter), "arrival", r))

        def schedule_inst(inst: SimInstance, t: float):
            if not inst.scheduled:
                inst.scheduled = True
                heapq.heappush(heap, (max(t, inst.busy_until),
                                      next(counter), "iter", inst))

        n_total = len(requests)
        while heap and len(self.completed) < n_total:
            t, _, kind, payload = heapq.heappop(heap)
            if t > max_sim_time:
                break
            self.now = t
            if kind == "arrival":
                self._on_arrival(payload)
                for inst in self.instances:
                    if inst.has_work():
                        schedule_inst(inst, t)
            else:
                inst = payload
                inst.scheduled = False
                n_running_before = len(inst.running)
                end, done = inst.iteration(t)
                self.completed.extend(done)
                # Only reschedule on PROGRESS (time advanced or a live batch);
                # an instance whose queued groups are entirely in flight
                # elsewhere would otherwise spin at constant sim time.
                progressed = end > t or inst.running or done
                if inst.has_work() and progressed:
                    schedule_inst(inst, end)
                if done:
                    if self.controller is not None:
                        self.controller.gc_groups()
                    # completions can unblock other instances' head groups
                    for other in self.instances:
                        if other is not inst and other.has_work():
                            schedule_inst(other, end)

        return self.metrics(requests)

    # ------------------------------------------------------------------
    def metrics(self, requests: Sequence[Request]) -> Dict[str, float]:
        done = [r for r in requests if r.finished()]
        with_ttft = [r for r in requests if r.ttft() is not None]
        makespan = max((r.completion_time for r in done), default=0.0)
        first_arrival = min((r.arrival_time for r in requests), default=0.0)
        span = max(makespan - first_arrival, 1e-9)
        slo_ok = [r for r in with_ttft if r.slo_met()]
        util = sum(i.stats.busy_time for i in self.instances) / (
            len(self.instances) * span)
        return {
            "policy": self.traits.name,
            "n_requests": float(len(requests)),
            "completed": float(len(done)),
            "slo_attainment": len(slo_ok) / max(len(requests), 1),
            "throughput_rps": len(done) / span,
            "token_throughput": sum(i.stats.tokens for i in self.instances) / span,
            "makespan": makespan,
            "device_utilization": util,
            "evictions": float(sum(i.stats.evictions for i in self.instances)),
            "preemptions": float(sum(i.stats.preemptions for i in self.instances)),
            "swaps": float(sum(i.stats.swaps for i in self.instances)),
            "mean_ttft": (sum(r.ttft() for r in with_ttft) / len(with_ttft))
                          if with_ttft else float("inf"),
            "mean_itl": (sum(r.itl() for r in done) / len(done))
                         if done else float("inf"),
        }
