"""Hardware / model profiles for the cluster simulator.

Constants follow the paper's testbed (§8: NVIDIA A10 24 GB and A100 80 GB;
Mistral-7B, Vicuna-13B, Llama-70B) with published vLLM-era numbers:

  * decode_per_token — per-iteration latency at saturated batch,
  * token_capacity  — KV tokens that fit after weights (paged, ~100% util),
  * swap_time       — CPU→GPU weight transfer (~25 GB/s PCIe 4),
  * prefill_time    — prefill cost per 1k prompt tokens,
  * inefficiency ε  — continuous-batching preemption factor.

The same dataclass is produced by ``calibrate_from_engine`` for reduced
models on CPU, so every simulator experiment can also run end-to-end
against the real JAX engine (tests do this).
"""
from __future__ import annotations

from typing import Dict

from repro.core.rwt_estimator import HardwareProfile

# (device, model) -> profile
_A100 = {
    "mistral-7b":   HardwareProfile(prefill_time=0.15, decode_per_token=0.025,
                                    inefficiency=1.2, token_capacity=120_000,
                                    swap_time=1.0, model_max_tokens=2048),
    "vicuna-13b":   HardwareProfile(prefill_time=0.20, decode_per_token=0.040,
                                    inefficiency=1.2, token_capacity=60_000,
                                    swap_time=2.0, model_max_tokens=2048),
    "llama-70b":    HardwareProfile(prefill_time=0.45, decode_per_token=0.110,
                                    inefficiency=1.25, token_capacity=40_000,
                                    swap_time=8.0, model_max_tokens=2048),
}
_A10 = {
    # ~3x less memory, ~2.5x slower; 70B does not fit on one A10
    "mistral-7b":   HardwareProfile(prefill_time=0.40, decode_per_token=0.065,
                                    inefficiency=1.25, token_capacity=18_000,
                                    swap_time=2.2, model_max_tokens=2048),
    "vicuna-13b":   HardwareProfile(prefill_time=0.60, decode_per_token=0.105,
                                    inefficiency=1.3, token_capacity=7_000,
                                    swap_time=4.5, model_max_tokens=2048),
}


def _with_ft_aliases(base: Dict[str, HardwareProfile]) -> Dict[str, HardwareProfile]:
    """Fine-tuned variants share the base model's profile (§8 W_B)."""
    out = dict(base)
    alias = {
        "mistral-7b-ft": "mistral-7b",
        "vicuna-13b-ft": "vicuna-13b",
        "vicuna-13b-ft2": "vicuna-13b",
        "llama-70b-ft1": "llama-70b",
        "llama-70b-ft2": "llama-70b",
    }
    for ft, b in alias.items():
        if b in base:
            out[ft] = base[b]
    return out


DEVICE_PROFILES: Dict[str, Dict[str, HardwareProfile]] = {
    "a100": _with_ft_aliases(_A100),
    "a10": _with_ft_aliases(_A10),
}


def profiles_for(device: str, models=None) -> Dict[str, HardwareProfile]:
    table = DEVICE_PROFILES[device]
    if models is None:
        return dict(table)
    return {m: table[m] for m in models if m in table}


def calibrate_from_engine(engine, token_capacity: int,
                          swap_time: float = 0.1,
                          model_max_tokens: int = 64,
                          dispatch_overhead: float = 0.0) -> HardwareProfile:
    """Paper §6 'Hardware Profiling': one batch run on the real engine.

    ``decode_per_token`` is measured at the engine's configured
    ``decode_burst`` (profile() drives ``steps()``), so the per-dispatch
    host overhead is already amortized INTO the measurement at that burst
    width; the profile carries the width so the simulator charges the same
    amortization.  Pass ``dispatch_overhead`` (absolute seconds per
    dispatch, e.g. derived from engine_bench's host_overhead_fraction x
    wall_us_per_iter) to model re-running the same instance at a DIFFERENT
    burst width without re-profiling."""
    import numpy as np
    # the longest calibration prompt that fits alongside the decode budget:
    # short prompts would extrapolate fixed per-step dispatch overhead into
    # the per-1k-token rate
    calib_prompt_tokens = max(8, min(64, engine.cfg.max_seq_len // 2))
    prompts = [np.random.randint(0, 100, size=calib_prompt_tokens)
               for _ in range(engine.cfg.max_slots)]
    # warm the jitted prefill/decode paths first: the cold compile would
    # otherwise dominate the measurement (and get extrapolated per-token)
    engine.profile([np.random.randint(0, 100, size=calib_prompt_tokens)],
                   max_new_tokens=2)
    prof = engine.profile(prompts, max_new_tokens=16)
    return HardwareProfile(
        # profile() measures per-admission wall time for the calibration
        # prompts; normalize to the per-1k-prompt-token rate the simulator
        # and HardwareProfile.prefill_seconds charge with
        prefill_time=prof["prefill_time"] * 1024.0 / calib_prompt_tokens,
        decode_per_token=prof["decode_per_token"],
        inefficiency=1.2,
        token_capacity=token_capacity,
        swap_time=swap_time,
        model_max_tokens=model_max_tokens,
        prefill_chunk_tokens=engine.cfg.prefill_chunk_tokens or None,
        # carry the model's window so sim/RWT chunk counts reproduce the
        # engine's window-clamped quantum (engine._chunk_quantum also caps
        # at max_seq_len, so mirror both bounds)
        sliding_window=None if engine.model.cfg.sliding_window is None
        else min(engine.model.cfg.sliding_window, engine.cfg.max_seq_len),
        # burst-aware dispatch accounting: the sim charges the per-dispatch
        # overhead once per decode_burst iterations, mirroring steps()
        decode_burst=max(engine.cfg.decode_burst, 1),
        dispatch_overhead=dispatch_overhead)
