"""Pallas TPU paged prefill-chunk attention kernel (flash-style, no gather).

The chunked-prefill serving path attends one right-padded chunk of queries
per sequence against (a) the sequence's already-written KV prefix, which
lives in the global page pool ``(num_blocks, KVH, block_size, D)`` named by
a per-sequence block table, and (b) the chunk's own fresh keys/values
(causal).  The XLA fallback densifies the WHOLE pre-chunk page pool slice
``(B, KVH, nb*bs, D)`` with a gather and concatenates the in-chunk keys —
an O(table) HBM copy per chunk that is quadratic over a long prompt.  This
kernel removes that copy: KV pages stream **in place** through the
SMEM-prefetched block table (the same ``PrefetchScalarGridSpec`` index_map
translation as the paged decode kernel) and an online softmax folds the
page-resident prefix and the causal in-chunk segment into one pass, so
per-chunk HBM reads are proportional to live tokens instead of the padded
pool, with no densified intermediate.

Grid (batch, kv_head, q_tile, prefix_tile + 1).  The GQA head-group's
chunk queries ride in ``(group, q_tile, D)`` tiles — chunks longer than
one tile (``prefill_chunk_tokens=512+``) are split across the third grid
dimension instead of blowing a single VMEM tile; ``auto_q_tile`` targets
128 query rows per tile (chunks <= 128 keep the old one-tile layout).
Every live page is fetched once per KV head per q tile.  Each prefix grid
step fetches ``pages_per_tile`` pages — replicated k/v inputs whose
index_maps read consecutive block-table entries — so small ``block_size``
pools still fill MXU tiles; the final grid step attends the causal
in-chunk segment and finalizes.  Tiles fully past ``starts[b]`` (the
sequence's prefix length) — and whole q tiles past ``valid[b]`` — skip
compute via ``pl.when``; dead prefix tiles skip their DMAs too: the
index_map clamps dead logical blocks to the last live one, so the block
index stops changing and the pipeline elides the copies.

Conventions (mirroring ``attend_prefill_chunk_paged``):
  * q: (B, H, C, D) chunk queries, row ``c`` at absolute position
    ``starts[b] + c``;
  * chunk_k / chunk_v: (B, KVH, C, D) the chunk's OWN keys/values (fresh
    projections — on the int8 path these stay float, exactly like the
    gather fallback, which only dequantizes the page-resident prefix);
  * block_table: (B, nb) physical page ids, sentinel entries >= num_blocks
    for unallocated logical blocks (clamped; masked by ``starts``);
  * starts: (B,) tokens already resident in pages (= the chunk's first
    absolute position); valid: (B,) real tokens in the chunk, 0 marking an
    inactive row whose output the caller ignores.

Every prefix position < starts[b] is visible to every chunk query (chunk
positions are all >= starts[b], so causality holds unconditionally there);
in-chunk key j is visible to query c iff ``j <= c`` and ``j < valid[b]``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams
from repro.kernels.paged_decode_attention import (
    NEG_INF,
    _assemble_kv_tile,
    _live_block_index,
    _online_softmax_update,
    _pad_block_table,
    auto_pages_per_tile,
)


_TARGET_Q_ROWS = 128


def auto_q_tile(chunk_len: int) -> int:
    """Query rows per q tile: the largest divisor of ``chunk_len`` that is
    <= ``_TARGET_Q_ROWS`` (power-of-two chunk buckets land exactly on 128).
    Chunks at or under the target keep the single-tile layout, as do
    awkward lengths whose only divisors are tiny (e.g. primes) — a sliver
    tile would re-fetch every live page once per handful of query rows,
    which is far worse than one wide tile."""
    if chunk_len <= _TARGET_Q_ROWS:
        return chunk_len
    for t in range(_TARGET_Q_ROWS, _TARGET_Q_ROWS // 8, -1):
        if chunk_len % t == 0:
            return t
    return chunk_len


def _make_prefill_kernel(*, P: int, nt: int, scale: float, block_size: int,
                         chunk_len: int, q_tile: int, group: int,
                         quant: bool):
    """Kernel body closure.  Tensor-ref layout after the 3 scalar-prefetch
    refs (block table, starts, valid):
      q, k_page*P, v_page*P, [k_scale*P, v_scale*P,] chunk_k, chunk_v,
      o, m_scr, l_scr, acc_scr

    The q tile is one ``q_tile``-query slice of the whole GQA group,
    (group, q_tile, D), flattened to (group * q_tile, D) rows for the
    matmuls; flattened row r is in-tile query position ``r % q_tile`` of
    head ``r // q_tile``, at absolute chunk position
    ``qi * q_tile + r % q_tile`` (``qi`` = q-tile grid index), so the
    causal chunk mask depends on the row only through that remainder.
    """
    rows_q = group * q_tile

    def kernel(bt_ref, st_ref, vd_ref, q_ref, *refs):
        del bt_ref  # consumed by the index_maps (page translation)
        k_refs = refs[:P]
        v_refs = refs[P:2 * P]
        if quant:
            ks_refs = refs[2 * P:3 * P]
            vs_refs = refs[3 * P:4 * P]
            ck_ref, cv_ref, o_ref, m_scr, l_scr, acc_scr = refs[4 * P:]
        else:
            ks_refs = vs_refs = None
            ck_ref, cv_ref, o_ref, m_scr, l_scr, acc_scr = refs[2 * P:]

        b = pl.program_id(0)
        qi = pl.program_id(2)
        t = pl.program_id(3)
        start = st_ref[b]   # tokens already resident in pages
        vd = vd_ref[b]      # real tokens in this row's chunk

        @pl.when(t == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        tile_rows = P * block_size
        k_start = t * tile_rows
        # whole q tiles past the row's live chunk skip compute (their
        # output rows are garbage the caller ignores; finalize emits the
        # zero-initialized scratch)
        q_live = qi * q_tile < vd

        def q2():
            return q_ref[0, 0].astype(jnp.float32).reshape(rows_q, -1)

        @pl.when(jnp.logical_and(jnp.logical_and(t < nt, k_start < start),
                                 q_live))
        def _prefix():
            k, v = _assemble_kv_tile(k_refs, v_refs, ks_refs, vs_refs, P)
            s = jax.lax.dot_general(q2(), k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            # prefix position of column r: k_start + r; live iff < start.
            # Chunk queries all sit at absolute positions >= start, so the
            # causal constraint is implied — only liveness is masked.
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(k_pos < start, s, NEG_INF)
            _online_softmax_update(s, v, m_scr, l_scr, acc_scr)

        @pl.when(jnp.logical_and(t == nt, q_live))
        def _chunk():
            k = ck_ref[0, 0].astype(jnp.float32)             # (C, D)
            v = cv_ref[0, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q2(), k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            shape = (rows_q, chunk_len)
            c_idx = qi * q_tile + jax.lax.rem(
                jax.lax.broadcasted_iota(jnp.int32, shape, 0), q_tile)
            j_idx = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
            mask = jnp.logical_and(j_idx <= c_idx, j_idx < vd)
            s = jnp.where(mask, s, NEG_INF)
            _online_softmax_update(s, v, m_scr, l_scr, acc_scr)

        @pl.when(t == nt)
        def _finalize():
            denom = jnp.maximum(l_scr[...], 1e-20)
            o_ref[0, 0] = (acc_scr[...] / denom[:, None]) \
                .reshape(group, q_tile, -1).astype(o_ref.dtype)

    return kernel


def _prefill_call(q, k_pages, v_pages, chunk_k, chunk_v, block_table,
                  starts, valid, scale_pages, *, pages_per_tile, q_tile,
                  interpret):
    """Shared pallas_call builder for the float / int8 twins
    (``scale_pages`` is None or the (k_scale, v_scale) pair)."""
    B, H, C, D = q.shape
    N, KVH, bs, _ = k_pages.shape
    nb = block_table.shape[1]
    assert nb >= 1, "block table must cover at least one logical block"
    assert H % KVH == 0
    group = H // KVH
    quant = scale_pages is not None
    scale = 1.0 / math.sqrt(D)

    P = pages_per_tile or auto_pages_per_tile(bs, nb)
    P = max(1, min(P, nb))
    nt = -(-nb // P)                 # prefix tiles; final grid step = chunk
    W = nt * P
    bt = _pad_block_table(block_table, N, W)
    Q = q_tile or auto_q_tile(C)
    Q = max(1, min(Q, C))
    if C % Q:
        Q = C                        # ragged chunk lengths keep one tile
    nq = C // Q
    # the GQA group's chunk queries ride in (group, Q, D) tiles (decode-
    # kernel pattern): pages are fetched once per KV head per q tile, not
    # once per q head
    qg = q.reshape(B, KVH, group, C, D)

    def _q_idx(b, h, qi, t, bt_ref, st_ref, vd_ref):
        return (b, h, 0, qi, 0)

    def _page_idx(b, h, qi, t, bt_ref, st_ref, vd_ref, *, p):
        # logical block t*P+p of sequence b -> physical page; blocks past
        # the live prefix (dead tiles AND the chunk grid step t == nt)
        # clamp to the last live block so their index never changes and
        # the pipeline skips the dead DMAs
        idx = _live_block_index(t * P + p, st_ref[b], bs, W)
        return (bt_ref[b, idx], h, 0, 0)

    def _scale_idx(b, h, qi, t, bt_ref, st_ref, vd_ref, *, p):
        idx = _live_block_index(t * P + p, st_ref[b], bs, W)
        return (bt_ref[b, idx], h, 0)

    def _chunk_idx(b, h, qi, t, bt_ref, st_ref, vd_ref):
        return (b, h, 0, 0)

    page_spec = lambda p: pl.BlockSpec(  # noqa: E731
        (1, 1, bs, D), functools.partial(_page_idx, p=p))
    in_specs = [pl.BlockSpec((1, 1, group, Q, D), _q_idx)]
    in_specs += [page_spec(p) for p in range(P)]
    in_specs += [page_spec(p) for p in range(P)]
    inputs = [qg] + [k_pages] * P + [v_pages] * P
    if quant:
        k_scale_pages, v_scale_pages = scale_pages
        sspec = lambda p: pl.BlockSpec(  # noqa: E731
            (1, 1, bs), functools.partial(_scale_idx, p=p))
        in_specs += [sspec(p) for p in range(P)]
        in_specs += [sspec(p) for p in range(P)]
        inputs += [k_scale_pages] * P + [v_scale_pages] * P
    in_specs += [pl.BlockSpec((1, 1, C, D), _chunk_idx),
                 pl.BlockSpec((1, 1, C, D), _chunk_idx)]
    inputs += [chunk_k, chunk_v]

    kernel = _make_prefill_kernel(P=P, nt=nt, scale=scale, block_size=bs,
                                  chunk_len=C, q_tile=Q, group=group,
                                  quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # block table + starts + valid, in SMEM
        grid=(B, KVH, nq, nt + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, Q, D), _q_idx),
        scratch_shapes=[
            pltpu.VMEM((group * Q,), jnp.float32),
            pltpu.VMEM((group * Q,), jnp.float32),
            pltpu.VMEM((group * Q, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, C, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(bt, starts.astype(jnp.int32), valid.astype(jnp.int32), *inputs)
    return out.reshape(B, H, C, D)


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, chunk_k: jax.Array,
                            chunk_v: jax.Array, block_table: jax.Array,
                            starts: jax.Array, valid: jax.Array, *,
                            pages_per_tile: int | None = None,
                            q_tile: int | None = None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, C, D); k_pages/v_pages: (N, KVH, bs, D); chunk_k/chunk_v:
    (B, KVH, C, D); block_table: (B, nb); starts/valid: (B,).  Returns
    (B, H, C, D) — rows past ``valid[b]`` (and rows of ``valid == 0``
    sequences) are garbage the caller must ignore, exactly like the gather
    fallback.  ``pages_per_tile=None`` auto-derives the kv-tile width from
    ``block_size`` (``auto_pages_per_tile``); ``q_tile=None`` auto-derives
    the query-tile height from the chunk length (``auto_q_tile`` — chunks
    past 128 queries split across grid steps instead of one VMEM tile)."""
    return _prefill_call(q, k_pages, v_pages, chunk_k, chunk_v, block_table,
                         starts, valid, None, pages_per_tile=pages_per_tile,
                         q_tile=q_tile, interpret=interpret)


def paged_prefill_attention_quant(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  k_scale_pages: jax.Array,
                                  v_scale_pages: jax.Array,
                                  chunk_k: jax.Array, chunk_v: jax.Array,
                                  block_table: jax.Array, starts: jax.Array,
                                  valid: jax.Array, *,
                                  pages_per_tile: int | None = None,
                                  q_tile: int | None = None,
                                  interpret: bool = False) -> jax.Array:
    """int8 page pool twin: k/v pages int8 with per-row scale pages
    (N, KVH, bs); the prefix dequantizes in VMEM while the in-chunk
    keys/values stay float (they are fresh projections — same contract as
    the gather fallback).  Same ``pages_per_tile`` / ``q_tile`` tiling."""
    return _prefill_call(q, k_pages, v_pages, chunk_k, chunk_v, block_table,
                         starts, valid, (k_scale_pages, v_scale_pages),
                         pages_per_tile=pages_per_tile, q_tile=q_tile,
                         interpret=interpret)
