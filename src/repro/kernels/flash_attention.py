"""Pallas TPU flash-attention (prefill) kernel.

Online-softmax tiling: grid (batch, q_heads, num_q_blocks, num_kv_blocks)
with the kv dimension innermost; running max / denominator / accumulator
live in VMEM scratch and persist across the kv grid steps (TPU grid
iteration is sequential).  GQA is handled in the k/v ``index_map`` (query
head h reads kv head ``h // group``) so kv tiles are fetched once per
group without materializing repeated heads in HBM.

Tiles default to (128, head_dim): MXU-aligned (multiples of 8×128 lanes)
and well under the ~16 MiB/core VMEM budget:
  q (128, D) + k (128, D) + v (128, D) + acc (128, D) @ f32 ≈ 256 KiB for D=128.

Causal / sliding-window masking is applied per-tile; fully-masked tiles
skip the matmul via ``pl.when``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_q: int,
                  seq_kv: int, causal: bool, window):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def needed():
        if not causal:
            live = True
        else:
            live = k_start <= q_start + block_q - 1  # any kv pos <= any q pos
        if window is not None:
            live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)
        return live

    @pl.when(needed() if (causal or window is not None) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_kv  # kv padding
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Lq, D); k/v: (B, KVH, Lkv, D) -> (B, H, Lq, D).

    Lq / Lkv are padded to tile multiples internally; padded kv positions are
    masked, padded q rows are sliced off.
    """
    B, H, Lq, D = q.shape
    KVH, Lkv = k.shape[1], k.shape[2]
    assert H % KVH == 0
    group = H // KVH
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, max(Lq, 8))
    block_k = min(block_k, max(Lkv, 8))
    pad_q = (-Lq) % block_q
    pad_k = (-Lkv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=Lq, seq_kv=Lkv, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Lq, :]
