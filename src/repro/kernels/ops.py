"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (Pallas
interprets the kernel body in Python) — selected automatically from the
backend; on TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _decode_attention
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.paged_decode_attention import (
    paged_decode_attention as _paged_decode_attention,
    paged_decode_attention_quant as _paged_decode_attention_quant,
)
from repro.kernels.paged_prefill_attention import (
    paged_prefill_attention as _paged_prefill_attention,
    paged_prefill_attention_quant as _paged_prefill_attention_quant,
)
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k: int = 256,
                     interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _decode_attention(q, k, v, lengths, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("pages_per_tile", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           pages_per_tile: int | None = None,
                           interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _paged_decode_attention(q, k_pages, v_pages, block_table, lengths,
                                   pages_per_tile=pages_per_tile,
                                   interpret=interp)


@functools.partial(jax.jit, static_argnames=("pages_per_tile", "interpret"))
def paged_decode_attention_quant(q, k_pages, v_pages, k_scale_pages,
                                 v_scale_pages, block_table, lengths, *,
                                 pages_per_tile: int | None = None,
                                 interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _paged_decode_attention_quant(q, k_pages, v_pages, k_scale_pages,
                                         v_scale_pages, block_table, lengths,
                                         pages_per_tile=pages_per_tile,
                                         interpret=interp)


@functools.partial(jax.jit, static_argnames=("pages_per_tile", "q_tile",
                                             "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, chunk_k, chunk_v,
                            block_table, starts, valid, *,
                            pages_per_tile: int | None = None,
                            q_tile: int | None = None,
                            interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _paged_prefill_attention(q, k_pages, v_pages, chunk_k, chunk_v,
                                    block_table, starts, valid,
                                    pages_per_tile=pages_per_tile,
                                    q_tile=q_tile, interpret=interp)


@functools.partial(jax.jit, static_argnames=("pages_per_tile", "q_tile",
                                             "interpret"))
def paged_prefill_attention_quant(q, k_pages, v_pages, k_scale_pages,
                                  v_scale_pages, chunk_k, chunk_v,
                                  block_table, starts, valid, *,
                                  pages_per_tile: int | None = None,
                                  q_tile: int | None = None,
                                  interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _paged_prefill_attention_quant(q, k_pages, v_pages, k_scale_pages,
                                          v_scale_pages, chunk_k, chunk_v,
                                          block_table, starts, valid,
                                          pages_per_tile=pages_per_tile,
                                          q_tile=q_tile, interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=interp)
