"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

One kernel computes the FULL scan for a (batch, head) slice: grid
(batch, heads, num_chunks) with the chunk dimension innermost and
"arbitrary" semantics — the inter-chunk state (N, P) is carried in VMEM
scratch across sequential grid steps, so the recurrence never round-trips
to HBM (the GPU implementation's inter-kernel state materialization is
exactly what we avoid; DESIGN.md §3).

Per chunk of length Q:
    y[i] = Σ_{j<=i} (C_i·B_j) exp(cum_i − cum_j) dt_j x_j   (intra, MXU)
         + C_i exp(cum_i) · h                               (inter)
    h'   = exp(cum_Q) h + Σ_j exp(cum_Q − cum_j) dt_j B_j ⊗ x_j

Tiles: x (Q, P), B/C (Q, N), dt (Q,) with Q=chunk_size (default 64),
N=d_state, P=head_dim — all ≤ (128, 128) f32 ⇒ < 1 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)
    h = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    B = b_ref[0, 0, 0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0, 0, 0].astype(jnp.float32)        # (Q, N)
    A = a_ref[h]                               # scalar (negative)

    log_a = dt * A                             # (Q,)
    cum = jnp.cumsum(log_a)                    # inclusive

    # intra-chunk quadratic form
    seg = cum[:, None] - cum[None, :]          # (Q, Q)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(iota_j <= iota_i, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk contribution from carried state
    h_prev = h_scr[...]                        # (N, P)
    c_in = C * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(c_in, h_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update
    decay_to_end = jnp.exp(cum[-1] - cum)      # (Q,)
    bw = B * (dt * decay_to_end)[:, None]      # (Q, N)
    new_state = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    h_scr[...] = jnp.exp(cum[-1]) * h_prev + new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int, *, interpret: bool = False) -> jax.Array:
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, G, N).
    Returns y (B, L, H, P).  L % chunk == 0 required.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0
    nc = L // chunk
    rep = H // G

    # head-major chunked layouts
    xh = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, chunk, P)
    dth = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, chunk)
    Bh = Bm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, chunk, N)
    Ch = Cm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    out = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # A, whole (H,)
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, ci: (b, h // rep, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, ci: (b, h // rep, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.astype(jnp.float32), xh, dth, Bh, Ch)
    return out.reshape(Bsz, H, L, P).transpose(0, 2, 1, 3)
