"""Pallas TPU paged decode-attention kernel (PagedAttention-style KV).

The KV cache is a single global page pool shared by every sequence in the
engine:

  k_pages / v_pages : (num_blocks, KVH, block_size, D)

Each sequence owns a list of physical pages named by its ``BlockManager``
block table; logical token position ``p`` of sequence ``b`` lives in page
``block_table[b, p // block_size]`` at row ``p % block_size``.  Pages are
physically non-contiguous, so the eviction / swapping / admission LSOs can
reclaim and reassign HBM at block granularity instead of per-slot
``max_seq_len`` stripes.

Grid (batch, kv_head, logical_block).  The block table and per-sequence
``lengths`` ride in scalar-prefetch SMEM (``PrefetchScalarGridSpec``), so
the k/v ``index_map`` can translate the logical block id into a physical
page id BEFORE the DMA is issued — the gather happens in the pipeline's
address computation, not as a materialized copy.  As in the dense kernel,
the whole GQA head-group's queries ride along in one tile and blocks fully
past ``lengths[b]`` skip compute via ``pl.when``.

``lengths`` counts every valid cache slot INCLUDING the newest token (the
same inclusive convention as ``decode_attention`` /
``decode_attention_quant`` — see those docstrings).

Follow-on (ROADMAP): fetch several pages per grid step so small
``block_size`` pools still feed the MXU with full tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         block_size: int):
    del bt_ref  # consumed by the index_maps (page translation), not the body
    b = pl.program_id(0)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    length = len_ref[b]  # valid tokens in this sequence (incl. newest)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = i * block_size

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)      # (block_size, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _paged_decode_quant_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                               vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                               scale: float, block_size: int):
    """int8 page pool: per-row scales live in their own scale pages and the
    dequant happens in VMEM (the HBM read stays int8 + scales)."""
    del bt_ref
    b = pl.program_id(0)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = i * block_size

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        ks = ks_ref[0, 0].astype(jnp.float32)    # (block_size,)
        vs = vs_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32) * ks[:, None]
        v = v_ref[0, 0].astype(jnp.float32) * vs[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _clamp_table(block_table: jax.Array, num_blocks: int) -> jax.Array:
    """Sentinel entries (>= num_blocks, marking unallocated logical blocks)
    are clamped to a real page so the prefetched index_map never addresses
    out of range; their contents are masked out by ``lengths``."""
    return jnp.minimum(block_table.astype(jnp.int32), num_blocks - 1)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (N, KVH, bs, D); block_table: (B, nb)
    physical page ids per logical block (entries >= N are sentinels for
    unallocated blocks); lengths: (B,) valid tokens INCLUDING the newest.
    Returns (B, H, D)."""
    B, H, D = q.shape
    N, KVH, bs, _ = k_pages.shape
    nb = block_table.shape[1]
    assert H % KVH == 0
    group = H // KVH
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KVH, group, D)
    bt = _clamp_table(block_table, N)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               block_size=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + lengths, prefetched to SMEM
        grid=(B, KVH, nb),
        in_specs=[
            pl.BlockSpec((1, 1, group, D),
                         lambda b, h, i, bt_ref, len_ref: (b, h, 0, 0)),
            # logical block i of sequence b -> physical page bt[b, i]
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, bt_ref, len_ref:
                         (bt_ref[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, bt_ref, len_ref:
                         (bt_ref[b, i], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D),
                               lambda b, h, i, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, H, D)


def paged_decode_attention_quant(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, k_scale_pages: jax.Array,
                                 v_scale_pages: jax.Array,
                                 block_table: jax.Array, lengths: jax.Array, *,
                                 interpret: bool = False) -> jax.Array:
    """int8 variant: k/v pages int8 (N, KVH, bs, D), scale pages
    (N, KVH, bs).  Same block-table / lengths conventions as
    ``paged_decode_attention``."""
    B, H, D = q.shape
    N, KVH, bs, _ = k_pages.shape
    nb = block_table.shape[1]
    assert H % KVH == 0
    group = H // KVH
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KVH, group, D)
    bt = _clamp_table(block_table, N)

    kernel = functools.partial(_paged_decode_quant_kernel, scale=scale,
                               block_size=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, nb),
        in_specs=[
            pl.BlockSpec((1, 1, group, D),
                         lambda b, h, i, bt_ref, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, bt_ref, len_ref:
                         (bt_ref[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, bt_ref, len_ref:
                         (bt_ref[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, i, bt_ref, len_ref: (bt_ref[b, i], h, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, i, bt_ref, len_ref: (bt_ref[b, i], h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D),
                               lambda b, h, i, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), qg, k_pages, v_pages,
      k_scale_pages, v_scale_pages)
    return out.reshape(B, H, D)


def gather_kv_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """XLA gather path: densify a sequence's pages via its block table.

    pages: (N, KVH, bs, D) [or (N, KVH, bs) for scales]; block_table:
    (B, nb) with sentinel entries >= N (clamped — their garbage contents
    must be masked by ``lengths`` downstream).
    Returns (B, KVH, nb * bs, D) [or (B, KVH, nb * bs)]: logical position p
    lands at row p (= block p // bs, offset p % bs).
    """
    N = pages.shape[0]
    g = pages[_clamp_table(block_table, N)]   # (B, nb, KVH, bs, ...)
    g = jnp.moveaxis(g, 2, 1)                 # (B, KVH, nb, bs, ...)
    B, KVH, nb, bs = g.shape[:4]
    return g.reshape((B, KVH, nb * bs) + g.shape[4:])
