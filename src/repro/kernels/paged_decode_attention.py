"""Pallas TPU paged decode-attention kernel (PagedAttention-style KV).

The KV cache is a single global page pool shared by every sequence in the
engine:

  k_pages / v_pages : (num_blocks, KVH, block_size, D)

Each sequence owns a list of physical pages named by its ``BlockManager``
block table; logical token position ``p`` of sequence ``b`` lives in page
``block_table[b, p // block_size]`` at row ``p % block_size``.  Pages are
physically non-contiguous, so the eviction / swapping / admission LSOs can
reclaim and reassign HBM at block granularity instead of per-slot
``max_seq_len`` stripes.

Grid (batch, kv_head, kv_tile).  The block table and per-sequence
``lengths`` ride in scalar-prefetch SMEM (``PrefetchScalarGridSpec``), so
the k/v ``index_map`` can translate logical block ids into physical page
ids BEFORE the DMA is issued — the gather happens in the pipeline's
address computation, not as a materialized copy.  Each kv tile fetches
``pages_per_tile`` pages (replicated k/v inputs whose index_maps read
consecutive block-table entries), so small ``block_size`` pools still fill
MXU tiles; ``pages_per_tile=None`` auto-derives the width from
``block_size`` (``auto_pages_per_tile`` targets 128-row tiles).  As in the
dense kernel, the whole GQA head-group's queries ride along in one tile;
tiles fully past ``lengths[b]`` skip compute via ``pl.when`` and skip
their DMAs too (dead logical blocks clamp to the last live one in the
index_map, so the unchanged block index pipeline-elides the copy).

``lengths`` counts every valid cache slot INCLUDING the newest token (the
same inclusive convention as ``decode_attention`` /
``decode_attention_quant`` — see those docstrings).

The chunked-prefill twin (same page pool, chunk queries, online softmax
over prefix pages + the causal in-chunk segment) lives in
``kernels/paged_prefill_attention.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30

# Target kv-tile rows per grid step: one MXU-aligned 128-row tile.  A pool
# with block_size 8 fetches 16 pages per step, block_size 128+ fetches 1.
_TARGET_TILE_ROWS = 128


def auto_pages_per_tile(block_size: int, nb: int) -> int:
    """Pages fetched per grid step so a kv tile approaches 128 rows
    (``_TARGET_TILE_ROWS``) without exceeding the table width ``nb``."""
    p = max(1, _TARGET_TILE_ROWS // max(block_size, 1))
    return max(1, min(p, nb))


def _pad_block_table(block_table: jax.Array, num_blocks: int,
                     width: int) -> jax.Array:
    """Clamp sentinel entries (>= num_blocks, marking unallocated logical
    blocks) to a real page and right-pad the table to ``width`` so every
    ``t * P + p`` index the replicated page specs compute stays in range.
    Clamped/padded entries are masked out by ``lengths`` / ``starts``."""
    bt = _clamp_table(block_table, num_blocks)
    nb = bt.shape[1]
    if width > nb:
        bt = jnp.pad(bt, ((0, 0), (0, width - nb)))
    return bt


def _clamp_table(block_table: jax.Array, num_blocks: int) -> jax.Array:
    """Sentinel entries are clamped to a real page so gathers never address
    out of range; their contents are masked out by ``lengths``."""
    return jnp.minimum(block_table.astype(jnp.int32), num_blocks - 1)


def _live_block_index(logical: jax.Array, tokens: jax.Array,
                      block_size: int, width: int) -> jax.Array:
    """Clamp a logical block index to the LAST LIVE block of a sequence
    holding ``tokens`` valid tokens (and to the padded table width).

    Used inside the page index_maps: tiles wholly past the live prefix
    resolve to the same page as the last live block, so consecutive grid
    steps see an unchanged block index and the Pallas pipeline SKIPS the
    dead tiles' DMAs entirely (``pl.when`` alone only skips compute, not
    the fetch).  The duplicated fetches read already-masked positions, so
    contents never leak into the output."""
    last_live = jnp.maximum((tokens + block_size - 1) // block_size, 1) - 1
    return jnp.minimum(jnp.minimum(logical, last_live), width - 1)


def _online_softmax_update(s, v, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step shared by the paged decode and
    prefill-chunk kernels: fold score tile ``s`` (rows_q, rows_kv) and
    value tile ``v`` (rows_kv, D) into the running max / denominator /
    accumulator scratch."""
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _assemble_kv_tile(k_refs, v_refs, ks_refs, vs_refs, P: int):
    """Concatenate the P replicated page refs into one (P*bs, D) f32 k/v
    tile, fusing the per-row int8 dequant in VMEM when scale refs are
    given (shared by the decode and prefill-chunk kernels)."""
    if ks_refs is not None:
        k_parts = [k_refs[p][0, 0].astype(jnp.float32)
                   * ks_refs[p][0, 0].astype(jnp.float32)[:, None]
                   for p in range(P)]
        v_parts = [v_refs[p][0, 0].astype(jnp.float32)
                   * vs_refs[p][0, 0].astype(jnp.float32)[:, None]
                   for p in range(P)]
    else:
        k_parts = [k_refs[p][0, 0].astype(jnp.float32) for p in range(P)]
        v_parts = [v_refs[p][0, 0].astype(jnp.float32) for p in range(P)]
    k = k_parts[0] if P == 1 else jnp.concatenate(k_parts, axis=0)
    v = v_parts[0] if P == 1 else jnp.concatenate(v_parts, axis=0)
    return k, v


def _make_decode_kernel(*, P: int, scale: float, block_size: int,
                        quant: bool):
    """Kernel body closure.  Tensor-ref layout after the 2 scalar-prefetch
    refs (block table, lengths):
      q, k_page*P, v_page*P, [k_scale*P, v_scale*P,] o, m_scr, l_scr, acc_scr
    """

    def kernel(bt_ref, len_ref, q_ref, *refs):
        del bt_ref  # consumed by the index_maps (page translation)
        k_refs = refs[:P]
        v_refs = refs[P:2 * P]
        if quant:
            ks_refs = refs[2 * P:3 * P]
            vs_refs = refs[3 * P:4 * P]
            o_ref, m_scr, l_scr, acc_scr = refs[4 * P:]
        else:
            ks_refs = vs_refs = None
            o_ref, m_scr, l_scr, acc_scr = refs[2 * P:]

        b = pl.program_id(0)
        i = pl.program_id(2)
        nt = pl.num_programs(2)
        length = len_ref[b]  # valid tokens in this sequence (incl. newest)

        @pl.when(i == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        tile_rows = P * block_size
        k_start = i * tile_rows

        @pl.when(k_start < length)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32)      # (group, d)
            # per-row scales live in their own scale pages; the dequant
            # happens in VMEM (the HBM read stays int8 + scales)
            k, v = _assemble_kv_tile(k_refs, v_refs, ks_refs, vs_refs, P)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(k_pos < length, s, NEG_INF)
            _online_softmax_update(s, v, m_scr, l_scr, acc_scr)

        @pl.when(i == nt - 1)
        def _finalize():
            denom = jnp.maximum(l_scr[...], 1e-20)
            o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)

    return kernel


def _decode_call(q, k_pages, v_pages, block_table, lengths, scale_pages, *,
                 pages_per_tile, interpret):
    """Shared pallas_call builder for the float / int8 twins
    (``scale_pages`` is None or the (k_scale, v_scale) pair)."""
    B, H, D = q.shape
    N, KVH, bs, _ = k_pages.shape
    nb = block_table.shape[1]
    assert H % KVH == 0
    group = H // KVH
    quant = scale_pages is not None
    scale = 1.0 / math.sqrt(D)

    P = pages_per_tile or auto_pages_per_tile(bs, nb)
    P = max(1, min(P, nb))
    nt = -(-nb // P)
    W = nt * P
    qg = q.reshape(B, KVH, group, D)
    bt = _pad_block_table(block_table, N, W)

    def _q_idx(b, h, i, bt_ref, len_ref):
        return (b, h, 0, 0)

    def _page_idx(b, h, i, bt_ref, len_ref, *, p):
        # logical block i*P+p of sequence b -> physical page; blocks past
        # the live prefix clamp to the last live block so dead tiles keep
        # an unchanged index and their DMAs are pipeline-skipped
        idx = _live_block_index(i * P + p, len_ref[b], bs, W)
        return (bt_ref[b, idx], h, 0, 0)

    def _scale_idx(b, h, i, bt_ref, len_ref, *, p):
        idx = _live_block_index(i * P + p, len_ref[b], bs, W)
        return (bt_ref[b, idx], h, 0)

    page_spec = lambda p: pl.BlockSpec(  # noqa: E731
        (1, 1, bs, D), functools.partial(_page_idx, p=p))
    in_specs = [pl.BlockSpec((1, 1, group, D), _q_idx)]
    in_specs += [page_spec(p) for p in range(P)]
    in_specs += [page_spec(p) for p in range(P)]
    inputs = [qg] + [k_pages] * P + [v_pages] * P
    if quant:
        k_scale_pages, v_scale_pages = scale_pages
        sspec = lambda p: pl.BlockSpec(  # noqa: E731
            (1, 1, bs), functools.partial(_scale_idx, p=p))
        in_specs += [sspec(p) for p in range(P)]
        in_specs += [sspec(p) for p in range(P)]
        inputs += [k_scale_pages] * P + [v_scale_pages] * P

    kernel = _make_decode_kernel(P=P, scale=scale, block_size=bs, quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + lengths, prefetched to SMEM
        grid=(B, KVH, nt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, D), _q_idx),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), *inputs)
    return out.reshape(B, H, D)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array, *,
                           pages_per_tile: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (N, KVH, bs, D); block_table: (B, nb)
    physical page ids per logical block (entries >= N are sentinels for
    unallocated blocks); lengths: (B,) valid tokens INCLUDING the newest.
    ``pages_per_tile=None`` auto-derives the kv-tile width from
    ``block_size``.  Returns (B, H, D)."""
    return _decode_call(q, k_pages, v_pages, block_table, lengths, None,
                        pages_per_tile=pages_per_tile, interpret=interpret)


def paged_decode_attention_quant(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, k_scale_pages: jax.Array,
                                 v_scale_pages: jax.Array,
                                 block_table: jax.Array, lengths: jax.Array, *,
                                 pages_per_tile: int | None = None,
                                 interpret: bool = False) -> jax.Array:
    """int8 variant: k/v pages int8 (N, KVH, bs, D), scale pages
    (N, KVH, bs).  Same block-table / lengths / tile conventions as
    ``paged_decode_attention``."""
    return _decode_call(q, k_pages, v_pages, block_table, lengths,
                        (k_scale_pages, v_scale_pages),
                        pages_per_tile=pages_per_tile, interpret=interpret)


def gather_kv_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """XLA gather path: densify a sequence's pages via its block table.

    pages: (N, KVH, bs, D) [or (N, KVH, bs) for scales]; block_table:
    (B, nb) with sentinel entries >= N (clamped — their garbage contents
    must be masked by ``lengths`` downstream).
    Returns (B, KVH, nb * bs, D) [or (B, KVH, nb * bs)]: logical position p
    lands at row p (= block p // bs, offset p % bs).
    """
    N = pages.shape[0]
    g = pages[_clamp_table(block_table, N)]   # (B, nb, KVH, bs, ...)
    g = jnp.moveaxis(g, 2, 1)                 # (B, KVH, nb, bs, ...)
    B, KVH, nb, bs = g.shape[:4]
    return g.reshape((B, KVH, nb * bs) + g.shape[4:])


def gather_kv_pages_fused(a_pages: jax.Array, b_pages: jax.Array,
                          block_table: jax.Array):
    """One STACKED gather densifying two same-shaped page pools (k and v,
    or the k/v scale pair) through the block table — halves the gather
    count of the XLA fallback / oracle paths, which previously issued one
    gather per pool leaf (four on the int8 path).

    a_pages/b_pages: (N, KVH, bs, ...); returns the two
    (B, KVH, nb * bs, ...) dense views (same layout as
    ``gather_kv_pages``).

    Tradeoff: the ``stack`` nominally touches both WHOLE pools (2N pages)
    before the gather picks B*nb of them, trading copy bandwidth for
    gather count when XLA doesn't sink the gather through the concat.
    That's acceptable where this runs — the CPU oracle / ``paged-xla``
    parity backend — and the serving hot path (``paged-pallas``) never
    gathers at all: both paged kernels translate pages in their
    index_maps.
    """
    N = a_pages.shape[0]
    stacked = jnp.stack([a_pages, b_pages], axis=1)  # (N, 2, KVH, bs, ...)
    g = stacked[_clamp_table(block_table, N)]        # (B, nb, 2, KVH, bs, ...)
    g = jnp.moveaxis(g, 2, 0)                        # (2, B, nb, KVH, bs, ...)
    g = jnp.moveaxis(g, 3, 2)                        # (2, B, KVH, nb, bs, ...)
    two, B, KVH, nb, bs = g.shape[:5]
    g = g.reshape((two, B, KVH, nb * bs) + g.shape[5:])
    return g[0], g[1]
