"""Version compat for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back, across releases); resolve whichever this jax ships so the kernels
compile under both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
