"""Pallas TPU decode-attention kernel (one query token vs KV cache).

The serving hot loop: for each sequence in the continuous batch, attend its
single new query against ``lengths[b]`` cached tokens.  Grid
(batch, kv_heads, num_kv_blocks); the whole GQA head-group's queries
(group, D) ride along in one tile so each KV block is streamed HBM→VMEM
exactly once per group (decode is memory-bound — KV traffic IS the roofline
term, see EXPERIMENTS.md §Roofline).

Per-sequence ``lengths`` masking supports ragged continuous batches; blocks
entirely past ``lengths[b]`` skip compute via ``pl.when``.

Length convention (shared by BOTH the float and the int8 kernel, and by the
paged variants in ``paged_decode_attention.py``): ``lengths[b]`` counts
every valid cache slot INCLUDING the token written this decode step — the
caller writes the new token's k/v at slot ``pos`` and passes ``pos + 1``.
``attend_decode`` computes this once (``kv_valid``) and feeds every backend
from it, so the quant / non-quant / paged paths cannot drift apart.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    b = pl.program_id(0)
    length = len_ref[b]  # tokens valid in this sequence's cache (incl. new one)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _decode_quant_kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float, block_k: int):
    """int8-KV variant: dequantize per-row inside VMEM (the HBM read is the
    int8 payload + scales — the roofline memory term halves; §Perf H3)."""
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    b = pl.program_id(0)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        ks = ks_ref[0, 0].astype(jnp.float32)       # (bk,)
        vs = vs_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32) * ks[:, None]
        v = v_ref[0, 0].astype(jnp.float32) * vs[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_quant(q: jax.Array, k: jax.Array, v: jax.Array,
                           k_scale: jax.Array, v_scale: jax.Array,
                           lengths: jax.Array, *,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k/v int8 (B, KVH, S, D); scales (B, KVH, S).

    ``lengths`` uses the same inclusive convention as ``decode_attention``:
    it COUNTS the newest token (whose k/v sits at slot ``lengths - 1``).
    """
    B, H, D = q.shape
    KVH, S = k.shape[1], k.shape[2]
    assert H % KVH == 0
    group = H // KVH
    scale = 1.0 / math.sqrt(D)

    block_k = min(block_k, max(S, 8))
    pad_k = (-S) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad_k)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad_k)))
    nk = k.shape[2] // block_k
    qg = q.reshape(B, KVH, group, D)

    kernel = functools.partial(_decode_quant_kernel, scale=scale, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, ki: (b, h, ki)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, ki: (b, h, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v, k_scale, v_scale)
    return out.reshape(B, H, D)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D) single query per sequence; k/v: (B, KVH, S, D);
    lengths: (B,) int32 — number of valid cache slots (the new token's k/v
    must already be written at slot lengths-1... i.e. lengths INCLUDES it).
    Returns (B, H, D).
    """
    B, H, D = q.shape
    KVH, S = k.shape[1], k.shape[2]
    assert H % KVH == 0
    group = H // KVH
    scale = 1.0 / math.sqrt(D)

    block_k = min(block_k, max(S, 8))
    pad_k = (-S) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = k.shape[2] // block_k

    # (B, KVH, group, D) query layout: one tile per (b, kv-head)
    qg = q.reshape(B, KVH, group, D)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, prefetched whole
            pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, D)
