"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window=None) -> jax.Array:
    """q: (B, H, Lq, D); k/v: (B, KVH, Lkv, D)."""
    B, H, Lq, D = q.shape
    KVH, Lkv = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, KVH, group, Lq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Lq)[:, None]
    k_pos = jnp.arange(Lkv)[None, :]
    mask = jnp.ones((Lq, Lkv), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, D).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, H, D); k/v: (B, KVH, S, D); lengths: (B,)."""
    B, H, D = q.shape
    KVH, S = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, KVH, group, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    valid = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """XLA gather oracle for the paged kernel: densify each sequence's pages
    through its block table (one stacked gather for k+v), then run the
    dense decode reference.

    q: (B, H, D); k_pages/v_pages: (N, KVH, bs, D); block_table: (B, nb)
    physical page ids (sentinel entries >= N allowed — masked by lengths);
    lengths: (B,) valid tokens INCLUDING the newest one.
    """
    from repro.kernels.paged_decode_attention import gather_kv_pages_fused
    k, v = gather_kv_pages_fused(k_pages, v_pages, block_table)
    return decode_attention_ref(q, k, v, lengths)


def _prefill_chunk_ref(q, k_dense, v_dense, chunk_k, chunk_v, starts, valid):
    """Two-segment masked softmax shared by the paged prefill oracles:
    dense pre-chunk kv (B, KVH, S, D) + causal in-chunk segment."""
    B, H, C, D = q.shape
    KVH, S = k_dense.shape[1], k_dense.shape[2]
    group = H // KVH
    k_all = jnp.concatenate([k_dense, chunk_k], axis=2).astype(jnp.float32)
    v_all = jnp.concatenate([v_dense, chunk_v], axis=2).astype(jnp.float32)
    qg = q.reshape(B, KVH, group, C, D).astype(jnp.float32)
    s = jnp.einsum("bkgcd,bksd->bkgcs", qg, k_all) / math.sqrt(D)
    s_idx = jnp.arange(S)[None, None, :]                      # (1, 1, S)
    cache_mask = jnp.broadcast_to(s_idx < starts[:, None, None], (B, C, S))
    c_idx = jnp.arange(C)[None, :, None]                      # (1, C, 1)
    j_idx = jnp.arange(C)[None, None, :]                      # (1, 1, C)
    chunk_mask = jnp.broadcast_to(
        (j_idx <= c_idx) & (j_idx < valid[:, None, None]), (B, C, C))
    mask = jnp.concatenate([cache_mask, chunk_mask], axis=-1)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bksd->bkgcd", p, v_all)
    return o.reshape(B, H, C, D).astype(q.dtype)


def paged_prefill_attention_ref(q: jax.Array, k_pages: jax.Array,
                                v_pages: jax.Array, chunk_k: jax.Array,
                                chunk_v: jax.Array, block_table: jax.Array,
                                starts: jax.Array,
                                valid: jax.Array) -> jax.Array:
    """XLA gather oracle for the fused paged prefill-chunk kernel: densify
    the pre-chunk pages, append the in-chunk keys, and apply the same
    two-segment mask as ``attend_prefill_chunk_paged``'s fallback.

    q: (B, H, C, D); k_pages/v_pages: (N, KVH, bs, D); chunk_k/chunk_v:
    (B, KVH, C, D); block_table: (B, nb); starts/valid: (B,).  Rows past
    ``valid[b]`` are garbage (ignored by callers), matching the kernel.
    """
    from repro.kernels.paged_decode_attention import gather_kv_pages_fused
    k, v = gather_kv_pages_fused(k_pages, v_pages, block_table)
    return _prefill_chunk_ref(q, k, v, chunk_k, chunk_v, starts, valid)


def paged_prefill_attention_quant_ref(q: jax.Array, k_pages: jax.Array,
                                      v_pages: jax.Array,
                                      k_scale_pages: jax.Array,
                                      v_scale_pages: jax.Array,
                                      chunk_k: jax.Array, chunk_v: jax.Array,
                                      block_table: jax.Array,
                                      starts: jax.Array,
                                      valid: jax.Array) -> jax.Array:
    """int8 twin of ``paged_prefill_attention_ref``: the page-resident
    prefix dequantizes through gathered scale pages; the in-chunk k/v stay
    float (fresh projections)."""
    from repro.kernels.paged_decode_attention import gather_kv_pages_fused
    k, v = gather_kv_pages_fused(k_pages, v_pages, block_table)
    ks, vs = gather_kv_pages_fused(k_scale_pages, v_scale_pages, block_table)
    k = k.astype(jnp.float32) * ks.astype(jnp.float32)[..., None]
    v = v.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
    return _prefill_chunk_ref(q, k.astype(q.dtype), v.astype(q.dtype),
                              chunk_k, chunk_v, starts, valid)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Naive recurrent SSD (same contract as kernels.ssd_scan, zero init)."""
    from repro.models.ssm import ssd_recurrent_reference
    y, _ = ssd_recurrent_reference(x, dt, A, Bm, Cm)
    return y
