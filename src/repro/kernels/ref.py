"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window=None) -> jax.Array:
    """q: (B, H, Lq, D); k/v: (B, KVH, Lkv, D)."""
    B, H, Lq, D = q.shape
    KVH, Lkv = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, KVH, group, Lq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Lq)[:, None]
    k_pos = jnp.arange(Lkv)[None, :]
    mask = jnp.ones((Lq, Lkv), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, D).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, H, D); k/v: (B, KVH, S, D); lengths: (B,)."""
    B, H, D = q.shape
    KVH, S = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, KVH, group, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    valid = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """XLA gather oracle for the paged kernel: densify each sequence's pages
    through its block table, then run the dense decode reference.

    q: (B, H, D); k_pages/v_pages: (N, KVH, bs, D); block_table: (B, nb)
    physical page ids (sentinel entries >= N allowed — masked by lengths);
    lengths: (B,) valid tokens INCLUDING the newest one.
    """
    from repro.kernels.paged_decode_attention import gather_kv_pages
    k = gather_kv_pages(k_pages, block_table)
    v = gather_kv_pages(v_pages, block_table)
    return decode_attention_ref(q, k, v, lengths)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Naive recurrent SSD (same contract as kernels.ssd_scan, zero init)."""
    from repro.models.ssm import ssd_recurrent_reference
    y, _ = ssd_recurrent_reference(x, dt, A, Bm, Cm)
    return y
