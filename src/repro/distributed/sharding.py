"""Logical-axis sharding rules → NamedSharding trees.

Model code annotates every param/cache leaf with logical axis names
(right-aligned against the leaf's shape — stacked layer/site dims are
implicitly replicated).  ``ShardingRules`` maps logical names to mesh axes;
``build_shardings`` applies the map with a divisibility guard: a logical
axis whose dimension does not divide the mesh axis size is REPLICATED
instead (GSPMD rejects uneven input shardings) and reported, so the
roofline pass can see what was dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[str, Tuple[str, ...], None]


# default rules: TP over "model", DP over ("pod","data") for batch
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "vocab": "model",
    "embed": None,
    "embed_in": None,
    "ff": "model",
    "moe_ff": None,
    "heads_x_dim": "model",
    "kv_heads_x_dim": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    # data-side axes
    "batch": ("pod", "data"),
    "kv_heads": None,
    "kv_seq": "model",
}


@dataclasses.dataclass
class ShardingRules:
    rules: Dict[str, MeshAxes]
    dropped: List[str] = dataclasses.field(default_factory=list)

    @staticmethod
    def default(overrides: Optional[Dict[str, MeshAxes]] = None) -> "ShardingRules":
        r = dict(DEFAULT_RULES)
        if overrides:
            r.update(overrides)
        return ShardingRules(r)

    # ------------------------------------------------------------------
    def _axis_size(self, mesh: Mesh, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return size

    def spec_for(self, mesh: Mesh, shape: Tuple[int, ...],
                 logical: Tuple[Optional[str], ...],
                 leaf_name: str = "") -> PartitionSpec:
        """Right-align ``logical`` against ``shape``; drop non-divisible."""
        ndim = len(shape)
        pad = ndim - len(logical)
        assert pad >= 0, (shape, logical, leaf_name)
        full = (None,) * pad + tuple(logical)
        entries: List[MeshAxes] = []
        for dim, name in zip(shape, full):
            axes = self.rules.get(name) if name is not None else None
            if axes is None:
                entries.append(None)
                continue
            axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
            # mesh may not have all axes (single-pod has no "pod")
            axes_t = tuple(a for a in axes_t if a in mesh.shape)
            size = 1
            for a in axes_t:
                size *= mesh.shape[a]
            if not axes_t:
                entries.append(None)
            elif dim % size != 0:
                self.dropped.append(f"{leaf_name}:{name}({dim}%{size})")
                entries.append(None)
            else:
                entries.append(axes_t[0] if len(axes_t) == 1 else axes_t)
        # PartitionSpec can't repeat a mesh axis: keep first occurrence
        used: set = set()
        cleaned: List[MeshAxes] = []
        for e in entries:
            if e is None:
                cleaned.append(None)
                continue
            et = (e,) if isinstance(e, str) else tuple(e)
            et = tuple(a for a in et if a not in used)
            used.update(et)
            if not et:
                cleaned.append(None)
            else:
                cleaned.append(et[0] if len(et) == 1 else et)
        return PartitionSpec(*cleaned)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def build_shardings(mesh: Mesh, struct_tree, axes_tree, rules: ShardingRules):
    """struct_tree: pytree of arrays/ShapeDtypeStructs; axes_tree: same
    treedef with logical-axes tuples at the leaves (axes tuples are leaves).
    Returns a pytree of NamedSharding."""
    flat_struct = jax.tree_util.tree_flatten_with_path(struct_tree)[0]
    # axes_tree leaves are tuples -> use is_leaf
    flat_axes, axes_def = jax.tree_util.tree_flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    struct_leaves, struct_def = jax.tree_util.tree_flatten(struct_tree)
    assert len(flat_axes) == len(struct_leaves), (
        f"axes tree ({len(flat_axes)}) != struct tree ({len(struct_leaves)})")
    shardings = []
    for (path, leaf), ax in zip(flat_struct, flat_axes):
        spec = rules.spec_for(mesh, tuple(leaf.shape), ax, _leaf_name(path))
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(struct_def, shardings)


def batch_axes_tree(batch_struct: Dict[str, Any]) -> Dict[str, Tuple]:
    """Data inputs: shard axis 0 (batch) over ("pod","data")."""
    out = {}
    for k, v in batch_struct.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), tree)
