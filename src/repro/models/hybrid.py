"""Zamba2-style hybrid: Mamba2 backbone + ONE weight-tied (shared) attention
block applied every ``hybrid_attn_every`` layers. [arXiv:2411.15242]

The shared block is stored once (not stacked); each application site keeps
its own KV cache.  In long-context mode the model config's sliding window
(set by the launcher for long_500k) bounds the materialized cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, ssm as ssm_lib


def attn_sites(cfg) -> list:
    """Layer indices after which the shared attention block runs."""
    return [i for i in range(cfg.num_layers) if (i + 1) % cfg.hybrid_attn_every == 0]


def init_hybrid_lm(key, cfg, dtype=jnp.float32):
    ke, kb, ka, km = jax.random.split(key, 4)
    block_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_mamba_residual_block(k, cfg, dtype))(block_keys)
    shared = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.init_attention(ka, cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp": layers.init_swiglu_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "shared_attn": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def init_mamba_residual_block(key, cfg, dtype=jnp.float32):
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "mamba": ssm_lib.init_mamba_block(key, cfg, dtype),
    }


def hybrid_param_axes(cfg):
    return {
        "embed": ("vocab", "embed"),
        "blocks": {"norm": ("embed",), "mamba": ssm_lib.mamba_param_axes(cfg)},
        "shared_attn": {
            "attn_norm": ("embed",),
            "attn": attention.attention_param_axes(cfg),
            "mlp_norm": ("embed",),
            "mlp": {"gate": ("embed", "ff"), "up": ("embed", "ff"),
                    "down": ("ff", "embed")},
        },
        "final_norm": ("embed",),
    }


def _shared_attn_full(params, cfg, x, positions):
    sp = params["shared_attn"]
    h = layers.rms_norm(x, sp["attn_norm"], cfg.rms_norm_eps)
    x = x + attention.attend_train(sp["attn"], cfg, h, positions)
    h = layers.rms_norm(x, sp["mlp_norm"], cfg.rms_norm_eps)
    return x + layers.swiglu_mlp(sp["mlp"], h)


def forward_train(params, cfg, x: jax.Array, positions: jax.Array,
                  *, remat: bool = True):
    """x: (B, L, d) embeddings -> hidden (B, L, d).

    Each mamba layer (and each shared-attention application) is a remat
    boundary: the SSD intra-chunk decay tensors (B, nc, Q, Q, H) are the
    dominant live activations and must not persist across 38 layers
    (EXPERIMENTS §Perf, zamba2 row)."""
    sites = set(attn_sites(cfg))

    def mamba_layer(x, bp):
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        out, _ = ssm_lib.mamba_block_full(bp["mamba"], cfg, h)
        return x + out

    def attn_layer(x, positions):
        return _shared_attn_full(params, cfg, x, positions)

    if remat:
        mamba_layer = jax.checkpoint(mamba_layer)
        attn_layer = jax.checkpoint(attn_layer)

    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        x = mamba_layer(x, bp)
        if i in sites:
            x = attn_layer(x, positions)
    return layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)


def loss_fn(params, cfg, batch, *, remat: bool = True):
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = params["embed"][inputs]
    positions = jnp.arange(x.shape[1])[None, :]
    hidden = forward_train(params, cfg, x, positions, remat=remat)
    logits = layers.mask_padded_logits((hidden @ params["embed"].T).astype(jnp.float32), cfg.vocab_size)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_seq: int, dtype=jnp.float32):
    n_sites = len(attn_sites(cfg))
    conv = ssm_lib.init_conv_state(cfg, batch, dtype)
    ssst = ssm_lib.init_ssm_state(cfg, batch, dtype)
    kv = attention.init_kv_cache(cfg, batch, max_seq, dtype)
    return {
        "conv": jnp.broadcast_to(conv[None], (cfg.num_layers,) + conv.shape),
        "ssm": jnp.broadcast_to(ssst[None], (cfg.num_layers,) + ssst.shape),
        "kv": jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_sites,) + a.shape), kv),
    }


def prefill(params, cfg, tokens: jax.Array, state):
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1])[None, :]
    sites = attn_sites(cfg)
    new_conv, new_ssm, new_kv = [], [], []
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        out, st = ssm_lib.mamba_block_full(bp["mamba"], cfg, h)
        new_conv.append(st["conv"])
        new_ssm.append(st["ssm"])
        x = x + out
        if i in set(sites):
            site_idx = sites.index(i)
            sp = params["shared_attn"]
            h = layers.rms_norm(x, sp["attn_norm"], cfg.rms_norm_eps)
            cl = jax.tree.map(lambda a: a[site_idx], state["kv"])
            a_out, kv = attention.attend_prefill(sp["attn"], cfg, h, positions, cl)
            new_kv.append(kv)
            x = x + a_out
            h = layers.rms_norm(x, sp["mlp_norm"], cfg.rms_norm_eps)
            x = x + layers.swiglu_mlp(sp["mlp"], h)
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, -1] @ params["embed"].T, cfg.vocab_size)
    new_state = {
        "conv": jnp.stack(new_conv),
        "ssm": jnp.stack(new_ssm),
        "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
    }
    return logits, new_state


def decode_step(params, cfg, tokens: jax.Array, lengths: jax.Array, state):
    x = params["embed"][tokens[:, None]]
    sites = attn_sites(cfg)
    new_conv, new_ssm, new_kv = [], [], []
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        st = {"conv": state["conv"][i], "ssm": state["ssm"][i]}
        out, nst = ssm_lib.mamba_block_step(bp["mamba"], cfg, h, st)
        new_conv.append(nst["conv"])
        new_ssm.append(nst["ssm"])
        x = x + out
        if i in set(sites):
            site_idx = sites.index(i)
            sp = params["shared_attn"]
            h = layers.rms_norm(x, sp["attn_norm"], cfg.rms_norm_eps)
            cl = jax.tree.map(lambda a: a[site_idx], state["kv"])
            a_out, kv = attention.attend_decode(sp["attn"], cfg, h, lengths, cl)
            new_kv.append(kv)
            x = x + a_out
            h = layers.rms_norm(x, sp["mlp_norm"], cfg.rms_norm_eps)
            x = x + layers.swiglu_mlp(sp["mlp"], h)
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, 0] @ params["embed"].T, cfg.vocab_size)
    new_state = {
        "conv": jnp.stack(new_conv),
        "ssm": jnp.stack(new_ssm),
        "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
    }
    return logits, new_state
