from repro.models.model_factory import Model, batch_struct, build_model, materialize_batch

__all__ = ["Model", "batch_struct", "build_model", "materialize_batch"]
