"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Per-layer params are stacked on a leading ``layers`` axis and the forward
pass is a ``jax.lax.scan`` over blocks (keeps HLO size O(1) in depth — 95
layers for deepseek-67b — and gives the remat boundary for training).

Serving-cache donation contract: the engine jits ``decode_step(_paged)``
and ``prefill_chunk(_paged)`` with the cache pytree DONATED
(``jax.jit(..., donate_argnums)``), so every cache leaf here must be
update-in-place friendly — the functional ``.at[].set`` writes are the
only consumers of the incoming buffers, and any attention read of "the
cache as it was on entry" must be expressible against the post-write
arrays (see the donation notes in ``models/attention.py``; rolling SWA is
the one path that genuinely needs the pre-write copy).  The per-layer
``lax.scan`` keeps this property: the stacked cache rides as scan
xs/ys, which XLA aliases when the donated input allows it.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe as moe_lib


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg, dtype=jnp.float32):
    ka, km = jax.random.split(key)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.init_attention(ka, cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = layers.init_swiglu_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def block_param_axes(cfg):
    p = {
        "attn_norm": ("embed",),
        "attn": attention.attention_param_axes(cfg),
        "mlp_norm": ("embed",),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_param_axes(cfg)
    else:
        p["mlp"] = {"gate": ("embed", "ff"), "up": ("embed", "ff"),
                    "down": ("ff", "embed")}
    return p


def init_lm(key, cfg, dtype=jnp.float32):
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, dtype))(block_keys)
    p = {
        "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.vision is not None:
        kp = jax.random.fold_in(kh, 1)
        in_dim = cfg.vision.patch_embed_dim or cfg.d_model
        p["vision_proj"] = layers.dense_init(kp, in_dim, cfg.d_model, dtype)
    return p


def lm_param_axes(cfg):
    ax = {
        "embed": ("vocab", "embed"),
        "blocks": jax.tree.map(lambda a: a, block_param_axes(cfg)),
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("embed", "vocab")
    if cfg.vision is not None:
        ax["vision_proj"] = ("embed", "embed_in")
    return ax


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_train(cfg, x, positions, bp):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    x = x + attention.attend_train(bp["attn"], cfg, h, positions)
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, aux = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out, aux = layers.swiglu_mlp(bp["mlp"], h), jnp.float32(0.0)
    return x + out, aux


def _seq_shard(cfg, x):
    """Perf lever (EXPERIMENTS §Perf H1): keep residual activations sharded
    on the seq dim over the 'model' axis between blocks — cuts the saved
    remat residuals by the TP degree."""
    if not cfg.shard_activations_seq:
        return x
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x, P(U, "model", U))


def forward_train(params, cfg, x_embeds: jax.Array, positions: jax.Array,
                  *, remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x_embeds: (B, L, d) -> (hidden (B, L, d), total_aux_loss)."""
    block = functools.partial(_block_train, cfg)
    if remat:
        block = jax.checkpoint(block, static_argnums=())

    def scan_fn(carry, bp):
        x, aux = carry
        x, a = block(x, positions, bp)
        return (_seq_shard(cfg, x), aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (_seq_shard(cfg, x_embeds), jnp.float32(0.0)),
                               params["blocks"])
    return layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps), aux


def embed_tokens(params, cfg, tokens: jax.Array) -> jax.Array:
    return params["embed"][tokens]


def unembed(params, cfg, x: jax.Array) -> jax.Array:
    logits = x @ (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return layers.mask_padded_logits(logits, cfg.vocab_size)


def embed_vlm(params, cfg, tokens: jax.Array, patch_embeds: jax.Array) -> jax.Array:
    """VLM input: precomputed patch embeddings (stub frontend) projected and
    prepended to the token embeddings."""
    tok = embed_tokens(params, cfg, tokens)
    patches = patch_embeds @ params["vision_proj"]
    return jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)


def loss_fn(params, cfg, batch: Dict[str, jax.Array],
            *, remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE. batch: {"tokens": (B, S+1) int32[, "patch_embeds"]}"""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    if cfg.vision is not None:
        x = embed_vlm(params, cfg, inputs, batch["patch_embeds"])
        n_prefix = x.shape[1] - inputs.shape[1]
    else:
        x = embed_tokens(params, cfg, inputs)
        n_prefix = 0
    B, L, _ = x.shape
    positions = jnp.arange(L)[None, :]
    hidden, aux = forward_train(params, cfg, x, positions, remat=remat)
    hidden = hidden[:, n_prefix:]
    logits = unembed(params, cfg, hidden).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    total = ce + aux_w * aux / max(cfg.num_layers, 1)
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving paths
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.float32):
    """Stacked per-layer KV cache: leaves (layers, B, KVH, S, D)."""
    one = attention.init_kv_cache(cfg, batch, max_seq, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)


def _block_prefill(cfg, x, positions, bp, cache_layer):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    a, new_cache = attention.attend_prefill(bp["attn"], cfg, h, positions, cache_layer)
    x = x + a
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, _ = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out = layers.swiglu_mlp(bp["mlp"], h)
    return x + out, new_cache


def prefill(params, cfg, tokens: jax.Array, cache,
            patch_embeds: Optional[jax.Array] = None):
    """tokens: (B, L). Returns (last-position logits (B, V), new cache)."""
    if cfg.vision is not None:
        assert patch_embeds is not None
        x = embed_vlm(params, cfg, tokens, patch_embeds)
    else:
        x = embed_tokens(params, cfg, tokens)
    L = x.shape[1]
    positions = jnp.arange(L)[None, :]

    def scan_fn(x, inp):
        bp, cl = inp
        x, new_cl = _block_prefill(cfg, x, positions, bp, cl)
        return x, new_cl

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    return unembed(params, cfg, x[:, -1]), new_cache


def _block_prefill_chunk(cfg, x, positions, valid, bp, cache_layer):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    a, new_cache = attention.attend_prefill_chunk(bp["attn"], cfg, h,
                                                  positions, valid, cache_layer)
    x = x + a
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, _ = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out = layers.swiglu_mlp(bp["mlp"], h)
    return x + out, new_cache


def prefill_chunk(params, cfg, tokens: jax.Array, starts: jax.Array,
                  valid: jax.Array, cache):
    """One chunk of a chunked prefill over a continuous batch.

    tokens: (B, C) right-padded chunk tokens; starts: (B,) tokens already
    cached per sequence; valid: (B,) real tokens in each row (0 = inactive
    row: no cache writes, output ignored).  Returns (logits at each row's
    last valid position (B, V), new cache) — the logits are only meaningful
    for rows whose chunk is the final one of their prompt.
    """
    x = embed_tokens(params, cfg, tokens)
    B, C, _ = x.shape
    positions = starts[:, None] + jnp.arange(C)[None, :]

    def scan_fn(x, inp):
        bp, cl = inp
        x, new_cl = _block_prefill_chunk(cfg, x, positions, valid, bp, cl)
        return x, new_cl

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    last = jnp.clip(valid - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    return unembed(params, cfg, x_last), new_cache


def init_paged_cache(cfg, num_blocks: int, block_size: int,
                     dtype=jnp.float32):
    """Stacked per-layer KV page pool: leaves (layers, num_blocks, KVH,
    block_size, D) — no per-slot batch axis; sequences share the pool via
    their block tables."""
    one = attention.init_paged_kv_cache(cfg, num_blocks, block_size, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)


def _block_prefill_chunk_paged(cfg, x, positions, valid, block_table, bp,
                               cache_layer):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    a, new_cache = attention.attend_prefill_chunk_paged(
        bp["attn"], cfg, h, positions, valid, block_table, cache_layer)
    x = x + a
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, _ = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out = layers.swiglu_mlp(bp["mlp"], h)
    return x + out, new_cache


def prefill_chunk_paged(params, cfg, tokens: jax.Array, starts: jax.Array,
                        valid: jax.Array, block_table: jax.Array, cache):
    """``prefill_chunk`` against the paged KV pool: same contract, plus the
    per-sequence ``block_table`` (B, nb) naming the pages each row's chunk
    writes into (one table for all layers — each layer has its own pool).
    With ``cfg.use_pallas_attention`` every layer's chunk attention runs
    the fused paged prefill kernel (pages streamed in place, no gather)."""
    x = embed_tokens(params, cfg, tokens)
    B, C, _ = x.shape
    positions = starts[:, None] + jnp.arange(C)[None, :]

    def scan_fn(x, inp):
        bp, cl = inp
        x, new_cl = _block_prefill_chunk_paged(cfg, x, positions, valid,
                                               block_table, bp, cl)
        return x, new_cl

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    last = jnp.clip(valid - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    return unembed(params, cfg, x_last), new_cache


def _block_decode_paged(cfg, x, lengths, block_table, bp, cache_layer):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    a, new_cache = attention.attend_decode_paged(bp["attn"], cfg, h, lengths,
                                                 block_table, cache_layer)
    x = x + a
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, _ = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out = layers.swiglu_mlp(bp["mlp"], h)
    return x + out, new_cache


def decode_step_paged(params, cfg, tokens: jax.Array, lengths: jax.Array,
                      block_table: jax.Array, cache):
    """``decode_step`` against the paged KV pool (block_table: (B, nb))."""
    x = embed_tokens(params, cfg, tokens[:, None])

    def scan_fn(x, inp):
        bp, cl = inp
        x, new_cl = _block_decode_paged(cfg, x, lengths, block_table, bp, cl)
        return x, new_cl

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    return unembed(params, cfg, x[:, 0]), new_cache


def _block_decode(cfg, x, lengths, bp, cache_layer):
    h = layers.rms_norm(x, bp["attn_norm"], cfg.rms_norm_eps)
    a, new_cache = attention.attend_decode(bp["attn"], cfg, h, lengths, cache_layer)
    x = x + a
    h = layers.rms_norm(x, bp["mlp_norm"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        out, _ = moe_lib.apply_moe(bp["moe"], cfg, h)
    else:
        out = layers.swiglu_mlp(bp["mlp"], h)
    return x + out, new_cache


def decode_step(params, cfg, tokens: jax.Array, lengths: jax.Array, cache):
    """tokens: (B,) int32, lengths: (B,) current cache fill per sequence.
    Returns (logits (B, V), new cache)."""
    x = embed_tokens(params, cfg, tokens[:, None])

    def scan_fn(x, inp):
        bp, cl = inp
        x, new_cl = _block_decode(cfg, x, lengths, bp, cl)
        return x, new_cl

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    return unembed(params, cfg, x[:, 0]), new_cache
