"""Whisper-style encoder-decoder. [arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: callers provide
precomputed frame embeddings (B, num_frames, d_model).  Encoder blocks are
bidirectional (sinusoidal positions added to frame embeds); decoder blocks
are causal self-attention (with KV cache) + cross-attention to the encoder
output + GELU MLP.  Adaptation note (DESIGN.md): decoder uses RoPE instead
of whisper's learned positions — structurally equivalent for the serving /
scheduling experiments this framework targets.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_cross_attn(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": layers.dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": layers.dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": layers.dense_init(ko, cfg.num_heads * hd, d, dtype),
    }


def init_enc_block(key, cfg, dtype=jnp.float32):
    ka, km = jax.random.split(key)
    d = cfg.d_model
    return {
        "attn_norm_s": jnp.ones((d,), dtype), "attn_norm_b": jnp.zeros((d,), dtype),
        "attn": attention.init_attention(ka, cfg, dtype),
        "mlp_norm_s": jnp.ones((d,), dtype), "mlp_norm_b": jnp.zeros((d,), dtype),
        "mlp": layers.init_gelu_mlp(km, d, cfg.d_ff, dtype),
    }


def init_dec_block(key, cfg, dtype=jnp.float32):
    ka, kc, km = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "self_norm_s": jnp.ones((d,), dtype), "self_norm_b": jnp.zeros((d,), dtype),
        "self_attn": attention.init_attention(ka, cfg, dtype),
        "cross_norm_s": jnp.ones((d,), dtype), "cross_norm_b": jnp.zeros((d,), dtype),
        "cross_attn": _init_cross_attn(kc, cfg, dtype),
        "mlp_norm_s": jnp.ones((d,), dtype), "mlp_norm_b": jnp.zeros((d,), dtype),
        "mlp": layers.init_gelu_mlp(km, d, cfg.d_ff, dtype),
    }


def init_encdec_lm(key, cfg, dtype=jnp.float32):
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder.num_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    d = cfg.d_model
    return {
        "embed": layers.embed_init(ke, cfg.padded_vocab, d, dtype),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_final_s": jnp.ones((d,), dtype), "enc_final_b": jnp.zeros((d,), dtype),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(dec_keys),
        "final_s": jnp.ones((d,), dtype), "final_b": jnp.zeros((d,), dtype),
    }


def encdec_param_axes(cfg):
    attn_ax = attention.attention_param_axes(cfg)
    mlp_ax = {"fc1": ("embed", "ff"), "b1": ("ff",),
              "fc2": ("ff", "embed"), "b2": ("embed",)}
    return {
        "embed": ("vocab", "embed"),
        "enc_blocks": {
            "attn_norm_s": ("embed",), "attn_norm_b": ("embed",),
            "attn": attn_ax,
            "mlp_norm_s": ("embed",), "mlp_norm_b": ("embed",),
            "mlp": mlp_ax,
        },
        "enc_final_s": ("embed",), "enc_final_b": ("embed",),
        "dec_blocks": {
            "self_norm_s": ("embed",), "self_norm_b": ("embed",),
            "self_attn": attn_ax,
            "cross_norm_s": ("embed",), "cross_norm_b": ("embed",),
            "cross_attn": {"wq": ("embed", "heads_x_dim"),
                           "wk": ("embed", "kv_heads_x_dim"),
                           "wv": ("embed", "kv_heads_x_dim"),
                           "wo": ("heads_x_dim", "embed")},
            "mlp_norm_s": ("embed",), "mlp_norm_b": ("embed",),
            "mlp": mlp_ax,
        },
        "final_s": ("embed",), "final_b": ("embed",),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, cfg, frame_embeds: jax.Array) -> jax.Array:
    """frame_embeds: (B, F, d) precomputed (conv frontend stub)."""
    B, F, d = frame_embeds.shape
    x = frame_embeds + layers.sinusoidal_positions(F, d)[None].astype(frame_embeds.dtype)
    positions = jnp.arange(F)[None, :]

    def scan_fn(x, bp):
        h = layers.layer_norm(x, bp["attn_norm_s"], bp["attn_norm_b"], cfg.rms_norm_eps)
        x = x + attention.attend_train(bp["attn"], cfg, h, positions, bidirectional=True)
        h = layers.layer_norm(x, bp["mlp_norm_s"], bp["mlp_norm_b"], cfg.rms_norm_eps)
        return x + layers.gelu_mlp(bp["mlp"], h), None

    x, _ = jax.lax.scan(scan_fn, x, params["enc_blocks"])
    return layers.layer_norm(x, params["enc_final_s"], params["enc_final_b"], cfg.rms_norm_eps)


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------

def cross_kv(bp_cross, cfg, enc_out: jax.Array) -> Dict[str, jax.Array]:
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ bp_cross["wk"]).reshape(B, F, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ bp_cross["wv"]).reshape(B, F, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def cross_attend(bp_cross, cfg, x: jax.Array, ckv: Dict[str, jax.Array]) -> jax.Array:
    B, L, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ bp_cross["wq"]).reshape(B, L, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    out = attention._sdpa(q, ckv["k"], ckv["v"], None)
    out = out.transpose(0, 2, 1, 3).reshape(B, L, cfg.num_heads * hd)
    return out @ bp_cross["wo"]


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_block_full(cfg, x, positions, bp, ckv):
    h = layers.layer_norm(x, bp["self_norm_s"], bp["self_norm_b"], cfg.rms_norm_eps)
    x = x + attention.attend_train(bp["self_attn"], cfg, h, positions)
    h = layers.layer_norm(x, bp["cross_norm_s"], bp["cross_norm_b"], cfg.rms_norm_eps)
    x = x + cross_attend(bp["cross_attn"], cfg, h, ckv)
    h = layers.layer_norm(x, bp["mlp_norm_s"], bp["mlp_norm_b"], cfg.rms_norm_eps)
    return x + layers.gelu_mlp(bp["mlp"], h)


def loss_fn(params, cfg, batch, *, remat: bool = True):
    """batch: {"tokens": (B, S+1), "frame_embeds": (B, F, d)}"""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(params, cfg, batch["frame_embeds"])
    x = params["embed"][inputs]
    positions = jnp.arange(x.shape[1])[None, :]

    def scan_fn(x, bp):
        ckv = cross_kv(bp["cross_attn"], cfg, enc_out)
        return _dec_block_full(cfg, x, positions, bp, ckv), None

    body = jax.checkpoint(scan_fn) if remat else scan_fn
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.layer_norm(x, params["final_s"], params["final_b"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits((x @ params["embed"].T).astype(jnp.float32), cfg.vocab_size)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.float32):
    """Self-attn cache (layers, B, KVH, S, D) + cross K/V (layers, B, KVH, F, D)."""
    one = attention.init_kv_cache(cfg, batch, max_seq, dtype)
    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)
    F = cfg.encoder.num_frames
    hd = cfg.resolved_head_dim
    ckv = jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, F, hd), dtype)
    return {"self": self_cache, "cross_k": ckv, "cross_v": ckv}


def prefill(params, cfg, tokens: jax.Array, cache, frame_embeds: jax.Array):
    """Run encoder + decoder prompt; populate self cache and cross K/V."""
    enc_out = encode(params, cfg, frame_embeds)
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1])[None, :]

    def scan_fn(x, inp):
        bp, cl = inp
        ckv = cross_kv(bp["cross_attn"], cfg, enc_out)
        h = layers.layer_norm(x, bp["self_norm_s"], bp["self_norm_b"], cfg.rms_norm_eps)
        a, new_cl = attention.attend_prefill(bp["self_attn"], cfg, h, positions, cl)
        x = x + a
        h = layers.layer_norm(x, bp["cross_norm_s"], bp["cross_norm_b"], cfg.rms_norm_eps)
        x = x + cross_attend(bp["cross_attn"], cfg, h, ckv)
        h = layers.layer_norm(x, bp["mlp_norm_s"], bp["mlp_norm_b"], cfg.rms_norm_eps)
        x = x + layers.gelu_mlp(bp["mlp"], h)
        return x, (new_cl, ckv)

    x, (self_cache, ckvs) = jax.lax.scan(scan_fn, x, (params["dec_blocks"], cache["self"]))
    x = layers.layer_norm(x, params["final_s"], params["final_b"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, -1] @ params["embed"].T, cfg.vocab_size)
    new_cache = {"self": self_cache, "cross_k": ckvs["k"], "cross_v": ckvs["v"]}
    return logits, new_cache


def decode_step(params, cfg, tokens: jax.Array, lengths: jax.Array, cache):
    x = params["embed"][tokens[:, None]]

    def scan_fn(x, inp):
        bp, cl, ck, cv = inp
        h = layers.layer_norm(x, bp["self_norm_s"], bp["self_norm_b"], cfg.rms_norm_eps)
        a, new_cl = attention.attend_decode(bp["self_attn"], cfg, h, lengths, cl)
        x = x + a
        h = layers.layer_norm(x, bp["cross_norm_s"], bp["cross_norm_b"], cfg.rms_norm_eps)
        x = x + cross_attend(bp["cross_attn"], cfg, h, {"k": ck, "v": cv})
        h = layers.layer_norm(x, bp["mlp_norm_s"], bp["mlp_norm_b"], cfg.rms_norm_eps)
        x = x + layers.gelu_mlp(bp["mlp"], h)
        return x, new_cl

    x, self_cache = jax.lax.scan(
        scan_fn, x, (params["dec_blocks"], cache["self"], cache["cross_k"], cache["cross_v"]))
    x = layers.layer_norm(x, params["final_s"], params["final_b"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, 0] @ params["embed"].T, cfg.vocab_size)
    return logits, {"self": self_cache, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
