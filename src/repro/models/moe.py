"""Top-k MoE layer with capacity-bounded scatter dispatch.

TPU adaptation notes (DESIGN.md §3): instead of the GShard (T, E, C) one-hot
dispatch einsum — whose dispatch tensor is enormous for fine-grained expert
counts like qwen3's 128 — we compute per-token in-expert slot indices with a
sorted cumulative count and use scatter/gather.  XLA lowers the scatter to a
sort-based TPU scatter, and GSPMD shards the (E, C, d) dispatched activations
over the ``model`` mesh axis (expert parallelism), inserting the all-to-all
the paper's MoE-serving regime depends on.

Router: softmax over expert logits, top-k selection, probs renormalized over
the selected experts; Switch-style load-balance aux loss.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, cfg, dtype=jnp.float32):
    moe = cfg.moe
    d = cfg.d_model
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, F = moe.num_experts, moe.d_ff_expert
    std = 1.0 / math.sqrt(d)
    return {
        "router": layers.dense_init(kr, d, E, dtype),
        "gate": std * jax.random.truncated_normal(kg, -2, 2, (E, d, F), dtype),
        "up": std * jax.random.truncated_normal(ku, -2, 2, (E, d, F), dtype),
        "down": (1.0 / math.sqrt(F)) * jax.random.truncated_normal(kd, -2, 2, (E, F, d), dtype),
    }


def moe_param_axes(cfg):
    return {
        "router": ("embed", "experts"),
        "gate": ("experts", "embed", "moe_ff"),
        "up": ("experts", "embed", "moe_ff"),
        "down": ("experts", "moe_ff", "embed"),
    }


def _topk_routing(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: (T, E) -> (weights (T,k), expert_ids (T,k), aux_loss scalar)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize
    # Switch aux loss: E * sum_e (fraction_tokens_e * mean_prob_e)
    one_hot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)  # (T, k, E)
    tokens_per_expert = jnp.sum(one_hot, axis=(0, 1)) / (T * k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(tokens_per_expert * mean_prob)
    return top_p, top_ids, aux


def _dispatch_slots(expert_ids: jax.Array, capacity: int, E: int):
    """expert_ids: (N,) -> (keep (N,), slot (N,)) via stable-sort counting."""
    N = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_expert = expert_ids[order]
    idx = jnp.arange(N)
    seg_start = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    pos_sorted = idx - seg_start
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos < capacity  # capacity drop (overflow tokens pass via residual)
    slot = expert_ids * capacity + jnp.where(keep, pos, 0)
    return keep, slot


def _moe_tokens(params, xf: jax.Array, weights: jax.Array,
                expert_ids: jax.Array, capacity: int, E: int, k: int) -> jax.Array:
    """Scatter-dispatch + expert SwiGLU + gather-combine for a flat token
    block xf: (T, d).  vmapped over dispatch groups (see apply_moe)."""
    T, d = xf.shape
    flat_expert = expert_ids.reshape(-1)            # (T*k,)
    flat_weight = weights.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)

    keep, slot = _dispatch_slots(flat_expert, capacity, E)
    safe_slot = jnp.where(keep, slot, E * capacity)  # overflow bucket

    dispatched = jnp.zeros((E * capacity + 1, d), xf.dtype)
    dispatched = dispatched.at[safe_slot].set(xf[flat_token])
    dispatched = dispatched[:-1].reshape(E, capacity, d)

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatched, params["gate"],
                                  preferred_element_type=jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", dispatched, params["up"],
                    preferred_element_type=jnp.float32)
    expert_out = jnp.einsum("ecf,efd->ecd", (gate * up).astype(xf.dtype),
                            params["down"], preferred_element_type=jnp.float32)

    flat_out = expert_out.reshape(E * capacity, d)
    pair_out = jnp.where(keep[:, None], flat_out[jnp.where(keep, slot, 0)], 0.0)
    pair_out = pair_out * flat_weight[:, None].astype(pair_out.dtype)
    return jnp.zeros((T, d), xf.dtype).at[flat_token].add(pair_out.astype(xf.dtype))


def apply_moe(params, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out (B, L, d), aux_loss scalar).

    With ``moe.dispatch_groups = G`` (beyond-paper perf lever, EXPERIMENTS
    §Perf H2) tokens are dispatched in G groups aligned with the data mesh
    axis: the scatter/gather stays shard-local, the expert einsum carries a
    (G:data, E:model) 2-D sharding, and the combine lowers to one
    all-reduce — instead of GSPMD all-gathering every token to every device.
    Capacity is per-group, so routing decisions are identical in
    distribution (statistically) but not bitwise vs the ungrouped path.
    """
    moe = cfg.moe
    B, L, d = x.shape
    T = B * L
    E, k = moe.num_experts, moe.experts_per_token
    xf = x.reshape(T, d)

    logits = xf @ params["router"]
    weights, expert_ids, aux = _topk_routing(logits, k)  # (T,k)

    G = moe.dispatch_groups or 1
    if G == 1 or T % G != 0:
        capacity = max(int(math.ceil(T * k / E * moe.capacity_factor)), k)
        out = _moe_tokens(params, xf, weights, expert_ids, capacity, E, k)
        return out.reshape(B, L, d), aux.astype(jnp.float32)

    Tg = T // G
    capacity = max(int(math.ceil(Tg * k / E * moe.capacity_factor)), k)
    xg = xf.reshape(G, Tg, d)
    wg = weights.reshape(G, Tg, k)
    eg = expert_ids.reshape(G, Tg, k)
    out = jax.vmap(lambda xx, ww, ee: _moe_tokens(params, xx, ww, ee,
                                                  capacity, E, k))(xg, wg, eg)
    return out.reshape(B, L, d), aux.astype(jnp.float32)
