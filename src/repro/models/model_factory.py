"""Unified model interface over all assigned architecture families.

``build_model(cfg)`` returns a ``Model`` with:
  * ``init(key, dtype)``               -> params pytree
  * ``param_axes()``                   -> same-treedef logical-axis tree
  * ``loss(params, batch)``            -> (scalar, metrics)     [train_4k]
  * ``init_cache(batch, max_seq)``     -> decode cache/state pytree
  * ``cache_axes(batch, max_seq)``     -> logical axes for the cache
  * ``prefill(params, batch, cache)``  -> (last logits, cache)  [prefill_32k]
  * ``decode_step(params, cache, tokens, lengths)`` -> (logits, cache)
  * ``input_spec_extras(shape)``       -> modality-stub entries for input_specs
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models import attention, ssm as ssm_lib


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    param_axes: Callable
    loss: Callable
    init_cache: Callable
    cache_axes: Callable
    prefill: Callable
    decode_step: Callable
    # chunked-prefill continuation (params, cache, tokens, starts, valid) ->
    # (last-valid-position logits, cache); None = arch needs single-shot
    # prefill (SSM/hybrid state carry, enc-dec cross attention).
    prefill_chunk: Optional[Callable] = None
    # Paged-KV serving paths (block-table page pool instead of per-slot
    # dense arrays); None = arch has no pageable KV (SSM state, hybrid,
    # enc-dec cross attention).  Signatures mirror the dense twins plus a
    # (B, num_blocks_per_seq) block_table argument:
    #   init_paged_cache(num_blocks, block_size, dtype) -> cache pytree
    #   decode_step_paged(params, cache, tokens, lengths, block_table)
    #   prefill_chunk_paged(params, cache, tokens, starts, valid, block_table)
    init_paged_cache: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None
    prefill_chunk_paged: Optional[Callable] = None

    def eval_shape_params(self, dtype=jnp.float32):
        """Param ShapeDtypeStructs without allocation (for the dry-run)."""
        return jax.eval_shape(lambda k: self.init(k, dtype), jax.random.key(0))


def _kv_cache_axes_tree(cfg, stacked_dims: int = 1):
    """(layers, B, KVH, S, D) logical axes."""
    ax = (None,) * stacked_dims + ("batch", "kv_heads", "kv_seq", None)
    tree = {"k": ax, "v": ax}
    if cfg.kv_quant:
        sax = (None,) * stacked_dims + ("batch", "kv_heads", "kv_seq")
        tree["k_scale"] = sax
        tree["v_scale"] = sax
    return tree


def build_model(cfg: ModelConfig) -> Model:
    if cfg.arch_type in ("dense", "moe", "vlm"):
        return _build_transformer(cfg)
    if cfg.arch_type == "ssm":
        return _build_ssm(cfg)
    if cfg.arch_type == "hybrid":
        return _build_hybrid(cfg)
    if cfg.arch_type == "audio":
        return _build_encdec(cfg)
    raise ValueError(f"unknown arch_type {cfg.arch_type}")


# ---------------------------------------------------------------------------

def _build_transformer(cfg):
    def prefill_fn(params, batch, cache):
        return transformer.prefill(params, cfg, batch["tokens"], cache,
                                   patch_embeds=batch.get("patch_embeds"))

    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: transformer.init_lm(key, cfg, dtype),
        param_axes=lambda: transformer.lm_param_axes(cfg),
        loss=lambda params, batch, **kw: transformer.loss_fn(params, cfg, batch, **kw),
        init_cache=lambda batch, max_seq, dtype=jnp.float32:
            transformer.init_cache(cfg, batch, max_seq, dtype),
        cache_axes=lambda: _kv_cache_axes_tree(cfg),
        prefill=prefill_fn,
        decode_step=lambda params, cache, tokens, lengths:
            transformer.decode_step(params, cfg, tokens, lengths, cache),
        prefill_chunk=lambda params, cache, tokens, starts, valid:
            transformer.prefill_chunk(params, cfg, tokens, starts, valid, cache),
        init_paged_cache=lambda num_blocks, block_size, dtype=jnp.float32:
            transformer.init_paged_cache(cfg, num_blocks, block_size, dtype),
        decode_step_paged=lambda params, cache, tokens, lengths, block_table:
            transformer.decode_step_paged(params, cfg, tokens, lengths,
                                          block_table, cache),
        prefill_chunk_paged=lambda params, cache, tokens, starts, valid, block_table:
            transformer.prefill_chunk_paged(params, cfg, tokens, starts,
                                            valid, block_table, cache),
    )


def _build_ssm(cfg):
    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: ssm_lm.init_ssm_lm(key, cfg, dtype),
        param_axes=lambda: ssm_lm.ssm_lm_param_axes(cfg),
        loss=lambda params, batch, **kw: ssm_lm.loss_fn(params, cfg, batch, **kw),
        init_cache=lambda batch, max_seq, dtype=jnp.float32:
            ssm_lm.init_state(cfg, batch, max_seq, dtype),
        cache_axes=lambda: {
            "conv": (None, "batch", None, "ssm_inner"),
            "ssm": (None, "batch", "ssm_heads", None, None),
        },
        prefill=lambda params, batch, cache:
            ssm_lm.prefill(params, cfg, batch["tokens"], cache),
        decode_step=lambda params, cache, tokens, lengths:
            ssm_lm.decode_step(params, cfg, tokens, lengths, cache),
    )


def _build_hybrid(cfg):
    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: hybrid.init_hybrid_lm(key, cfg, dtype),
        param_axes=lambda: hybrid.hybrid_param_axes(cfg),
        loss=lambda params, batch, **kw: hybrid.loss_fn(params, cfg, batch, **kw),
        init_cache=lambda batch, max_seq, dtype=jnp.float32:
            hybrid.init_state(cfg, batch, max_seq, dtype),
        cache_axes=lambda: {
            "conv": (None, "batch", None, "ssm_inner"),
            "ssm": (None, "batch", "ssm_heads", None, None),
            "kv": _kv_cache_axes_tree(cfg),
        },
        prefill=lambda params, batch, cache:
            hybrid.prefill(params, cfg, batch["tokens"], cache),
        decode_step=lambda params, cache, tokens, lengths:
            hybrid.decode_step(params, cfg, tokens, lengths, cache),
    )


def _build_encdec(cfg):
    cross_ax = (None, "batch", "kv_heads", None, None)
    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: encdec.init_encdec_lm(key, cfg, dtype),
        param_axes=lambda: encdec.encdec_param_axes(cfg),
        loss=lambda params, batch, **kw: encdec.loss_fn(params, cfg, batch, **kw),
        init_cache=lambda batch, max_seq, dtype=jnp.float32:
            encdec.init_cache(cfg, batch, max_seq, dtype),
        cache_axes=lambda: {
            "self": _kv_cache_axes_tree(cfg),
            "cross_k": cross_ax,
            "cross_v": cross_ax,
        },
        prefill=lambda params, batch, cache:
            encdec.prefill(params, cfg, batch["tokens"], cache, batch["frame_embeds"]),
        decode_step=lambda params, cache, tokens, lengths:
            encdec.decode_step(params, cfg, tokens, lengths, cache),
    )


# ---------------------------------------------------------------------------
# modality stubs for input_specs
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, batch: int, seq: int, kind: str,
                 dtype=jnp.float32) -> Dict[str, Any]:
    """ShapeDtypeStructs (or concrete arrays via ``materialize_batch``) for
    one step's data inputs."""
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        out = {"tokens": sds((batch, seq + 1), jnp.int32)}
        if cfg.vision is not None:
            out["patch_embeds"] = sds(
                (batch, cfg.vision.num_patch_tokens,
                 cfg.vision.patch_embed_dim or cfg.d_model), dtype)
        if cfg.encoder is not None:
            out["frame_embeds"] = sds((batch, cfg.encoder.num_frames, cfg.d_model), dtype)
        return out
    if kind == "prefill":
        n_text = seq
        if cfg.vision is not None:
            n_text = max(seq - cfg.vision.num_patch_tokens, 1)
        out = {"tokens": sds((batch, n_text), jnp.int32)}
        if cfg.vision is not None:
            out["patch_embeds"] = sds(
                (batch, cfg.vision.num_patch_tokens,
                 cfg.vision.patch_embed_dim or cfg.d_model), dtype)
        if cfg.encoder is not None:
            out["frame_embeds"] = sds((batch, cfg.encoder.num_frames, cfg.d_model), dtype)
        return out
    if kind == "decode":
        return {"tokens": sds((batch,), jnp.int32),
                "lengths": sds((batch,), jnp.int32)}
    raise ValueError(kind)


def materialize_batch(cfg: ModelConfig, batch: int, seq: int, kind: str,
                      key, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Concrete random data matching ``batch_struct`` (smoke tests)."""
    structs = batch_struct(cfg, batch, seq, kind, dtype)
    out = {}
    for name, s in structs.items():
        key = jax.random.fold_in(key, hash(name) % (2 ** 31))
        if jnp.issubdtype(s.dtype, jnp.integer):
            if name == "lengths":
                out[name] = jnp.full(s.shape, seq - 1, jnp.int32)
            else:
                out[name] = jax.random.randint(key, s.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(key, s.shape, s.dtype) * 0.02
    return out
