"""Shared building blocks: norms, RoPE, MLPs, initializers.

All model code is functional: ``init_*`` builds a params pytree (nested
dicts of jnp arrays), ``*_apply`` consumes it.  Parallel "spec trees" with
the same treedef carry logical sharding axes per leaf (see
``repro.distributed.sharding``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (matches common LLM inits)."""
    std = 1.0 / math.sqrt(in_dim)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


def mask_padded_logits(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Mask the vocab-padding tail (see ModelConfig.padded_vocab)."""
    if logits.shape[-1] == vocab_size:
        return logits
    idx = jnp.arange(logits.shape[-1])
    return jnp.where(idx < vocab_size, logits, -1e30)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_mlp(params, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["gate"])
    return (gate * (x @ params["up"])) @ params["down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    """Whisper-style two-matrix GELU MLP (with biases)."""
    k1, k2 = jax.random.split(key)
    return {
        "fc1": dense_init(k1, d_model, d_ff, dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "fc2": dense_init(k2, d_ff, d_model, dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params["fc1"] + params["b1"])
    return h @ params["fc2"] + params["b2"]


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """(length, dim) fixed sinusoidal embeddings (whisper encoder)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * idx / dim))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """Boolean (q_len, kv_len) mask; True = attend. q_offset = absolute
    position of the first query (supports decode where q_len=1)."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def sliding_window_mask(q_len: int, kv_len: int, q_offset, window: int) -> jax.Array:
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    return (kv_pos <= q_pos) & (kv_pos > q_pos - window)
