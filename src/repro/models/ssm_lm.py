"""Attention-free Mamba2 LM (mamba2-130m). [arXiv:2405.21060]"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, ssm as ssm_lib


def init_ssm_lm(key, cfg, dtype=jnp.float32):
    ke, kb = jax.random.split(key)
    block_keys = jax.random.split(kb, cfg.num_layers)

    def init_one(k):
        return {"norm": jnp.ones((cfg.d_model,), dtype),
                "mamba": ssm_lib.init_mamba_block(k, cfg, dtype)}

    return {
        "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": jax.vmap(init_one)(block_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def ssm_lm_param_axes(cfg):
    return {
        "embed": ("vocab", "embed"),
        "blocks": {"norm": ("embed",), "mamba": ssm_lib.mamba_param_axes(cfg)},
        "final_norm": ("embed",),
    }


def forward_train(params, cfg, x: jax.Array, *, remat: bool = True) -> jax.Array:
    def block(x, bp):
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        out, _ = ssm_lib.mamba_block_full(bp["mamba"], cfg, h)
        return x + out

    body = jax.checkpoint(block) if remat else block

    def scan_fn(x, bp):
        return body(x, bp), None

    x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    return layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)


def loss_fn(params, cfg, batch, *, remat: bool = True):
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = params["embed"][inputs]
    hidden = forward_train(params, cfg, x, remat=remat)
    logits = layers.mask_padded_logits((hidden @ params["embed"].T).astype(jnp.float32), cfg.vocab_size)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def init_state(cfg, batch: int, max_seq: int, dtype=jnp.float32):
    conv = ssm_lib.init_conv_state(cfg, batch, dtype)
    ssst = ssm_lib.init_ssm_state(cfg, batch, dtype)
    return {
        "conv": jnp.broadcast_to(conv[None], (cfg.num_layers,) + conv.shape),
        "ssm": jnp.broadcast_to(ssst[None], (cfg.num_layers,) + ssst.shape),
    }


def prefill(params, cfg, tokens: jax.Array, state):
    x = params["embed"][tokens]

    def scan_fn(x, inp):
        bp, conv, ssst = inp
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        out, st = ssm_lib.mamba_block_full(bp["mamba"], cfg, h,
                                           {"conv": conv, "ssm": ssst})
        return x + out, (st["conv"], st["ssm"])

    x, (conv, ssst) = jax.lax.scan(scan_fn, x,
                                   (params["blocks"], state["conv"], state["ssm"]))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, -1] @ params["embed"].T, cfg.vocab_size)
    return logits, {"conv": conv, "ssm": ssst}


def decode_step(params, cfg, tokens: jax.Array, lengths: jax.Array, state):
    del lengths  # SSM state is position-free
    x = params["embed"][tokens[:, None]]

    def scan_fn(x, inp):
        bp, conv, ssst = inp
        h = layers.rms_norm(x, bp["norm"], cfg.rms_norm_eps)
        out, st = ssm_lib.mamba_block_step(bp["mamba"], cfg, h,
                                           {"conv": conv, "ssm": ssst})
        return x + out, (st["conv"], st["ssm"])

    x, (conv, ssst) = jax.lax.scan(scan_fn, x,
                                   (params["blocks"], state["conv"], state["ssm"]))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    logits = layers.mask_padded_logits(x[:, 0] @ params["embed"].T, cfg.vocab_size)
    return logits, {"conv": conv, "ssm": ssst}
