"""Mamba2 (SSD — state-space duality) block in JAX.

Discretized recurrence, per head h with scalar decay A_h < 0:

    a_t = exp(dt_t * A)                       (scalar per head)
    h_t = a_t * h_{t-1} + dt_t * B_t ⊗ x_t    (state:  (N, P))
    y_t = C_t · h_t + D * x_t

Train/prefill use the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk ``lax.scan`` over states) — matching arXiv:2405.21060 §6 — which
maps onto the MXU as batched (Q×Q)·(Q×P) matmuls; decode uses the O(1)
recurrent step.  A Pallas kernel for the intra-chunk part lives in
``repro.kernels.ssd_scan`` (this module is also its oracle's backbone).

Layout: x (B, L, H, P); B,C (B, L, G, N) with H/G heads per group;
state (B, H, N, P).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg, dtype=jnp.float32):
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    G, N, W = ssm.n_groups, ssm.d_state, ssm.conv_width
    conv_dim = di + 2 * G * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(k1, d, 2 * di + 2 * G * N + nh, dtype),
        "conv_w": 0.1 * jax.random.normal(k2, (W, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(dtype)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (nh,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(k4, di, d, dtype),
    }


def mamba_param_axes(cfg):
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm_scale": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (B, L, H, P)   dt: (B, L, H)   A: (H,) negative
    Bm: (B, L, G, N)   Cm: (B, L, G, N)
    init_state: (B, H, N, P) or None.
    Returns (y (B, L, H, P), final_state (B, H, N, P)).  L % chunk == 0.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B, L, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    # chunked views: (B, nc, Q, ...)
    xc = xf.reshape(Bsz, nc, chunk, H, P)
    dtc = dtf.reshape(Bsz, nc, chunk, H)
    Bc = Bf.reshape(Bsz, nc, chunk, H, N)
    Cc = Cf.reshape(Bsz, nc, chunk, H, N)

    log_a = dtc * A[None, None, None, :]          # (B, nc, Q, H), <= 0
    cum = jnp.cumsum(log_a, axis=2)               # inclusive cumsum within chunk

    # --- intra-chunk (quadratic attention-like form) ---
    # decay(i, j) = exp(cum_i - cum_j) for j <= i  (decay strictly after j)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnihs,bnjhs->bnijh", Cc, Bc)          # (B,nc,Q,Q,H)
    att = cb * decay * dtc[:, :, None, :, :]               # weight dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xc)

    # --- chunk states ---
    # state_c = sum_j exp(cum_Q - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,Q,H)
    weighted_x = xc * (dtc * decay_to_end)[..., None]      # (B,nc,Q,H,P)
    states = jnp.einsum("bnjhs,bnjhp->bnhsp", Bc, weighted_x)  # (B,nc,H,N,P)

    # --- inter-chunk recurrence over nc chunks ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)
    h0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        s, dcy = inp  # s: (B,H,N,P), dcy: (B,H)
        h_prev = h
        h = dcy[:, :, None, None] * h + s
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,N,P) state entering chunk

    # --- inter-chunk contribution ---
    in_decay = jnp.exp(cum)                                # decay from chunk start to i (inclusive)
    y_inter = jnp.einsum("bnihs,bnhsp->bnihp", Cc * in_decay[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), h_final


def ssd_step(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrent decode step.

    x: (B, H, P), dt: (B, H), Bm/Cm: (B, G, N), state: (B, H, N, P).
    """
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)   # (B, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    a = jnp.exp(dt.astype(jnp.float32) * A[None, :])       # (B, H)
    dBx = jnp.einsum("bhn,bhp->bhnp", Bf * dt.astype(jnp.float32)[..., None],
                     x.astype(jnp.float32))
    new_state = a[:, :, None, None] * state.astype(jnp.float32) + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Cf, new_state)
    return y.astype(x.dtype), new_state


def ssd_recurrent_reference(x, dt, A, Bm, Cm, init_state=None):
    """Naive per-token recurrence — oracle for ssd_chunked (tests)."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))

    def step(h, t):
        y, h = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(L))
    return ys.transpose(1, 0, 2, 3), h


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _split_proj(params, cfg, proj):
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    G, N = ssm.n_groups, ssm.d_state
    nh = ssm.num_heads(d)
    z, xBC, dt = jnp.split(proj, [di, di + di + 2 * G * N], axis=-1)
    return z, xBC, dt, di, G, N, nh


def init_conv_state(cfg, batch: int, dtype=jnp.float32) -> jax.Array:
    ssm = cfg.ssm
    conv_dim = ssm.d_inner(cfg.d_model) + 2 * ssm.n_groups * ssm.d_state
    return jnp.zeros((batch, ssm.conv_width - 1, conv_dim), dtype)


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> jax.Array:
    ssm = cfg.ssm
    nh = ssm.num_heads(cfg.d_model)
    return jnp.zeros((batch, nh, ssm.d_state, ssm.head_dim), jnp.float32)


def _causal_conv_full(xBC: jax.Array, w: jax.Array, b: jax.Array,
                      prev: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over (B, L, C); prev: (B, W-1, C) history.
    Returns (out (B, L, C), new_history)."""
    W = w.shape[0]
    B, L, C = xBC.shape
    hist = jnp.zeros((B, W - 1, C), xBC.dtype) if prev is None else prev
    padded = jnp.concatenate([hist, xBC], axis=1)  # (B, L+W-1, C)
    out = jnp.zeros((B, L, C), jnp.float32)
    for i in range(W):  # small fixed width: unrolled taps
        out = out + padded[:, i:i + L, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_hist = padded[:, L:, :] if L >= W - 1 else padded[:, -(W - 1):, :]
    return jax.nn.silu(out).astype(xBC.dtype), new_hist


def mamba_block_full(params, cfg, u: jax.Array,
                     init_states: Optional[Dict[str, jax.Array]] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence mamba2 block. u: (B, L, d) -> (out, states)."""
    ssm = cfg.ssm
    proj = u @ params["in_proj"]
    z, xBC, dt, di, G, N, nh = _split_proj(params, cfg, proj)
    prev_conv = init_states["conv"] if init_states else None
    xBC, conv_state = _causal_conv_full(xBC, params["conv_w"], params["conv_b"], prev_conv)
    x, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    Bsz, L, _ = u.shape
    P = ssm.head_dim
    x = x.reshape(Bsz, L, nh, P)
    Bm = Bm.reshape(Bsz, L, G, N)
    Cm = Cm.reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    prev_ssm = init_states["ssm"] if init_states else None
    # pad L to a multiple of chunk
    Q = ssm.chunk_size
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, ssm_state = ssd_chunked(x, dt, A, Bm, Cm, Q, prev_ssm)
    y = y[:, :L]
    x = x[:, :L]
    y = y + x * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, L, di)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        params["norm_scale"], cfg.rms_norm_eps)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": ssm_state}


def mamba_block_step(params, cfg, u: jax.Array, states: Dict[str, jax.Array]
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. u: (B, 1, d); states: {"conv": (B,W-1,C), "ssm": (B,H,N,P)}."""
    ssm = cfg.ssm
    proj = u[:, 0] @ params["in_proj"]  # (B, ·)
    z, xBC, dt, di, G, N, nh = _split_proj(params, cfg, proj[:, None, :])
    z, xBC, dt = z[:, 0], xBC[:, 0], dt[:, 0]
    # conv update
    W = ssm.conv_width
    hist = states["conv"]  # (B, W-1, C)
    window = jnp.concatenate([hist, xBC[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xBC_c = jax.nn.silu(conv_out).astype(u.dtype)
    new_hist = window[:, 1:, :]
    x, Bm, Cm = jnp.split(xBC_c, [di, di + G * N], axis=-1)
    Bsz = u.shape[0]
    P = ssm.head_dim
    x = x.reshape(Bsz, nh, P)
    Bm = Bm.reshape(Bsz, G, N)
    Cm = Cm.reshape(Bsz, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_state = ssd_step(x, dt, A, Bm, Cm, states["ssm"])
    y = y + x * params["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(Bsz, 1, di)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)[:, None, :],
                        params["norm_scale"], cfg.rms_norm_eps)
    return y @ params["out_proj"], {"conv": new_hist, "ssm": new_state}
