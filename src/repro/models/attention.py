"""GQA attention with full / sliding-window variants and KV-cache paths.

Three entry points per block:
  * ``attend_train``   — full-sequence causal attention (no cache).
  * ``attend_prefill`` — like train, but also returns the populated cache.
  * ``attend_decode``  — one query token against the cache (per-sequence
                         lengths; continuous batching friendly).

The dense KV cache is a dict ``{"k": (B, KVH, S, D), "v": (B, KVH, S, D)}``
plus per-sequence ``lengths`` carried by the caller.  Sliding-window models
keep a rolling cache of size ``window`` (write index = pos % window), so
the ``long_500k`` shape materializes only O(window) memory.

The paged serving twins (``attend_decode_paged`` /
``attend_prefill_chunk_paged``) replace the per-slot arrays with a global
page pool ``{"k": (num_blocks, KVH, block_size, D), ...}`` addressed
through per-sequence block tables (full attention only — see
``init_paged_kv_cache``).

Backend support matrix (``EngineConfig.attention_backend`` selects the
column; every cell is token-identical to ``xla``):

  capability           xla    pallas  paged-xla  paged-pallas
  chunked prefill      yes    yes(*)  yes        yes (fused kernel)
  paged KV pool        no     no      yes        yes (block-table kernels)
  int8 KV (kv_quant)   yes    yes     yes        yes (fused dequant)
  sliding window       yes    partial no         no
  decode kernel        jnp    Pallas  gather     Pallas multi-page tiles

  (*) "pallas" accelerates train/prefill (flash) and dense decode; the
  chunked-prefill chunk step itself uses the jnp two-segment path, and
  rolling SWA decode always falls back to jnp slot-validity masking.
  Paged backends require full attention + chunked prefill (engine gates).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_attention(key, cfg, dtype=jnp.float32):
    """cfg: ModelConfig (uses num_heads/num_kv_heads/head_dim/qkv_bias)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": layers.dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": layers.dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": layers.dense_init(ko, cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _project_qkv(params, cfg, x, positions):
    """x: (B, L, d) -> q (B, L, H, hd), k/v (B, L, KVH, hd), with RoPE."""
    B, L, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, L, cfg.num_heads, hd)
    k = k.reshape(B, L, cfg.num_kv_heads, hd)
    v = v.reshape(B, L, cfg.num_kv_heads, hd)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """q: (B, H, Lq, D), k/v: (B, KVH, Lkv, D), GQA by head-group reshape.

    mask: broadcastable to (B, 1, Lq, Lkv), True = attend.

    Perf note (EXPERIMENTS §Perf H3): operands stay in their storage dtype
    (bf16 on TPU) and accumulation happens in f32 via
    ``preferred_element_type`` — materializing ``.astype(f32)`` copies of
    q/k/v doubled the decode path's HBM traffic (the KV cache is the
    memory-roofline term for decode).
    """
    B, H, Lq, D = q.shape
    KVH = k.shape[1]
    group = H // KVH
    q = q.reshape(B, KVH, group, Lq, D)
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Lq, D).astype(v.dtype)


def _sdpa_q_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window, chunk: int) -> jax.Array:
    """Query-chunked exact attention (flash-style memory behaviour at the
    XLA level, EXPERIMENTS §Perf H1): peak score tensor is
    (B, KVH, group, chunk, Lkv) instead of (B, KVH, group, L, L).
    ``lax.map`` serializes chunks, so only one tile is live at a time."""
    B, H, L, D = q.shape
    Lkv = k.shape[2]
    assert L % chunk == 0, (L, chunk)
    nq = L // chunk

    def one(qi):
        q_off = qi * chunk
        qs = jax.lax.dynamic_slice_in_dim(q, q_off, chunk, axis=2)
        if window is not None:
            mask = layers.sliding_window_mask(chunk, Lkv, q_off, window)[None, None]
        elif causal:
            mask = layers.causal_mask(chunk, Lkv, q_off)[None, None]
        else:
            mask = None
        return _sdpa(qs, k, v, mask)  # (B, H, chunk, D)

    out = jax.lax.map(one, jnp.arange(nq))  # (nq, B, H, chunk, D)
    return out.transpose(1, 2, 0, 3, 4).reshape(B, H, L, D)


def attend_train(params, cfg, x: jax.Array, positions: jax.Array,
                 *, bidirectional: bool = False) -> jax.Array:
    """Full-sequence attention. x: (B, L, d)."""
    B, L, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = q.transpose(0, 2, 1, 3)  # (B, H, L, D)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    chunk = cfg.train_attn_chunk
    if cfg.use_pallas_attention and not bidirectional:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(q, k, v, causal=True,
                                         window=cfg.sliding_window)
    elif chunk is not None and not bidirectional and L % chunk == 0 and L > chunk:
        out = _sdpa_q_chunked(q, k, v, causal=True,
                              window=cfg.sliding_window, chunk=chunk)
    else:
        if bidirectional:
            mask = None
        elif cfg.sliding_window is not None:
            mask = layers.sliding_window_mask(L, L, 0, cfg.sliding_window)[None, None]
        else:
            mask = layers.causal_mask(L, L, 0)[None, None]
        out = _sdpa(q, k, v, mask)
    out = out.transpose(0, 2, 1, 3).reshape(B, L, cfg.num_heads * cfg.resolved_head_dim)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def cache_len(cfg, max_seq: int) -> int:
    """Materialized cache length: rolling window for SWA, else max_seq."""
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    S = cache_len(cfg, max_seq)
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.num_kv_heads, S, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], dtype),
                "v_scale": jnp.zeros(shape[:-1], dtype)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(cfg, num_blocks: int, block_size: int,
                        dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Global KV page pool (PagedAttention layout), one per layer.

    Unlike ``init_kv_cache`` there is NO per-slot batch axis: every sequence
    in the engine shares the pool and owns pages named by its
    ``BlockManager`` block table, so engine KV capacity is
    ``num_blocks * block_size`` tokens total rather than
    ``max_slots * max_seq_len``.  Logical position ``p`` of a sequence lives
    in page ``block_table[p // block_size]`` at row ``p % block_size``.
    """
    hd = cfg.resolved_head_dim
    shape = (num_blocks, cfg.num_kv_heads, block_size, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], dtype),
                "v_scale": jnp.zeros(shape[:-1], dtype)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., D) -> (int8 values, per-row scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(x.dtype)


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def attend_prefill(params, cfg, x: jax.Array, positions: jax.Array,
                   cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal attention over the prompt AND cache population.

    Assumes prefill starts at position 0 and ``positions`` are
    [0..L) per sequence (right-padded batches use the padding mask upstream
    via lengths in decode).  x: (B, L, d).
    """
    B, L, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)  # (B, KVH, L, D)
    vh = v.transpose(0, 2, 1, 3)
    if cfg.sliding_window is not None:
        mask = layers.sliding_window_mask(L, L, 0, cfg.sliding_window)[None, None]
    else:
        mask = layers.causal_mask(L, L, 0)[None, None]
    out = _sdpa(qh, kh, vh, mask)
    out = out.transpose(0, 2, 1, 3).reshape(B, L, cfg.num_heads * cfg.resolved_head_dim)

    S = cache["k"].shape[2]
    if cfg.sliding_window is not None and L > S:
        # keep only the last `window` tokens, aligned to rolling index
        # rolling write index after L tokens is L % S; we store the last S
        # tokens such that slot (p % S) holds position p.
        last = jnp.arange(L - S, L)
        slots = last % S
        kh_tail = kh[:, :, L - S:, :]
        vh_tail = vh[:, :, L - S:, :]
        if cfg.kv_quant:
            kq, ks = _quantize_kv(kh_tail)
            vq, vs = _quantize_kv(vh_tail)
            return out @ params["wo"], {
                "k": jnp.zeros_like(cache["k"]).at[:, :, slots, :].set(kq),
                "v": jnp.zeros_like(cache["v"]).at[:, :, slots, :].set(vq),
                "k_scale": jnp.zeros_like(cache["k_scale"]).at[:, :, slots].set(ks),
                "v_scale": jnp.zeros_like(cache["v_scale"]).at[:, :, slots].set(vs),
            }
        new_k = jnp.zeros_like(cache["k"]).at[:, :, slots, :].set(kh_tail)
        new_v = jnp.zeros_like(cache["v"]).at[:, :, slots, :].set(vh_tail)
    else:
        pad = S - L
        if cfg.kv_quant:
            kq, ks = _quantize_kv(kh)
            vq, vs = _quantize_kv(vh)
            if pad > 0:
                kq = jnp.pad(kq, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vq = jnp.pad(vq, ((0, 0), (0, 0), (0, pad), (0, 0)))
                ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad)))
                vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad)))
            return out @ params["wo"], {"k": kq, "v": vq,
                                        "k_scale": ks, "v_scale": vs}
        new_k = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad > 0 else kh
        new_v = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad > 0 else vh
    return out @ params["wo"], {"k": new_k, "v": new_v}


def attend_prefill_chunk(params, cfg, x: jax.Array, positions: jax.Array,
                         valid: jax.Array,
                         cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunk-granular prefill continuation (chunked-prefill serving path).

    Attends this chunk's queries against the already-populated cache plus
    the chunk's own keys, and writes the chunk's k/v into the cache at their
    absolute positions (rolling slots for SWA).

    x: (B, C, d) right-padded chunk embeddings; positions: (B, C) absolute
    token positions (``starts[:, None] + arange(C)``); valid: (B,) number of
    real tokens in each row's chunk — 0 marks an inactive row whose writes
    are dropped and whose outputs the caller ignores.

    The attention is computed in two kv segments so a rolling SWA cache
    never reads a slot this same chunk just overwrote: the PRE-chunk cache
    (positions <= start-1) and the in-chunk keys (read from the fresh
    projections).

    Donation note: for FULL attention the pre-chunk segment reads the
    POST-write cache — the chunk writes land at slots >= start while the
    segment mask only passes slots < start, so the values are identical
    and the (donated) cache buffer has no consumer besides the in-place
    update, letting XLA skip the per-chunk pool copy.  Rolling SWA keeps
    the pre-write read (slot aliasing: this chunk may overwrite slots the
    mask still passes), which forces a copy when donated — correctness
    first.
    """
    B, C, _ = x.shape
    S = cache["k"].shape[2]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, positions)  # k/v: (B, C, KVH, hd)
    starts = positions[:, 0]

    # ---- cache write: slot = pos (full) / pos % S (rolling SWA) ----------
    in_chunk = jnp.arange(C)[None, :] < valid[:, None]          # (B, C)
    slot = positions % S if cfg.sliding_window is not None else positions
    write_slot = jnp.where(in_chunk, slot, S)                    # S => dropped
    b_idx = jnp.arange(B)[:, None]
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": cache["k"].at[b_idx, :, write_slot, :].set(kq, mode="drop"),
            "v": cache["v"].at[b_idx, :, write_slot, :].set(vq, mode="drop"),
            "k_scale": cache["k_scale"].at[b_idx, :, write_slot].set(ks, mode="drop"),
            "v_scale": cache["v_scale"].at[b_idx, :, write_slot].set(vs, mode="drop"),
        }
        read = cache if cfg.sliding_window is not None else new_cache
        old_k = _dequantize_kv(read["k"], read["k_scale"], x.dtype)
        old_v = _dequantize_kv(read["v"], read["v_scale"], x.dtype)
    else:
        new_cache = {
            "k": cache["k"].at[b_idx, :, write_slot, :].set(k, mode="drop"),
            "v": cache["v"].at[b_idx, :, write_slot, :].set(v, mode="drop"),
        }
        read = cache if cfg.sliding_window is not None else new_cache
        old_k, old_v = read["k"], read["v"]

    # ---- attention: [pre-chunk cache | in-chunk keys] --------------------
    qh = q.transpose(0, 2, 1, 3)                                 # (B, H, C, hd)
    kh = k.transpose(0, 2, 1, 3)                                 # (B, KVH, C, hd)
    vh = v.transpose(0, 2, 1, 3)
    k_all = jnp.concatenate([old_k, kh], axis=2)                 # (B, KVH, S+C, hd)
    v_all = jnp.concatenate([old_v, vh], axis=2)

    q_pos = positions[:, :, None]                                # (B, C, 1)
    s_idx = jnp.arange(S)[None, None, :]                         # (1, 1, S)
    if cfg.sliding_window is not None:
        # slot s of the PRE-chunk cache holds the largest position
        # p <= start-1 with p % S == s (negative => never written).
        prev = (starts - 1)[:, None, None]
        p_s = prev - ((prev - s_idx) % S)
        cache_mask = (p_s >= 0) & (p_s > q_pos - cfg.sliding_window)
    else:
        cache_mask = jnp.broadcast_to(s_idx < starts[:, None, None], (B, C, S))
    j_idx = jnp.arange(C)[None, None, :]
    p_j = starts[:, None, None] + j_idx
    chunk_mask = (p_j <= q_pos) & (j_idx < valid[:, None, None])
    if cfg.sliding_window is not None:
        chunk_mask = chunk_mask & (p_j > q_pos - cfg.sliding_window)
    mask = jnp.concatenate([cache_mask, chunk_mask], axis=-1)[:, None]

    out = _sdpa(qh, k_all, v_all, mask)
    out = out.transpose(0, 2, 1, 3).reshape(B, C, cfg.num_heads * hd)
    return out @ params["wo"], new_cache


def attend_decode(params, cfg, x: jax.Array, lengths: jax.Array,
                  cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d); lengths: (B,) tokens already cached
    (i.e. the new token's absolute position).  Returns (out, new_cache).
    """
    B = x.shape[0]
    S = cache["k"].shape[2]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, lengths[:, None])
    # write new k/v at slot (rolling for SWA)
    slot = lengths % S if cfg.sliding_window is not None else lengths
    k_new = k[:, 0]  # (B, KVH, D)
    v_new = v[:, 0]
    batch_idx = jnp.arange(B)
    new_cache = {}
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        new_cache = {
            "k": cache["k"].at[batch_idx, :, slot, :].set(kq),
            "v": cache["v"].at[batch_idx, :, slot, :].set(vq),
            "k_scale": cache["k_scale"].at[batch_idx, :, slot].set(ks),
            "v_scale": cache["v_scale"].at[batch_idx, :, slot].set(vs),
        }
        new_k = _dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        new_v = _dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        new_k = cache["k"].at[batch_idx, :, slot, :].set(k_new)
        new_v = cache["v"].at[batch_idx, :, slot, :].set(v_new)

    # ONE length convention for every decode backend: the cache now holds
    # kv_valid = lengths + 1 tokens (the new token's k/v was just written at
    # slot `lengths`), and the kernels/masks below all consume kv_valid.
    # The kernel-side contract (count INCLUDES the newest token) is
    # documented in kernels/decode_attention.py and locked in by the
    # quant-vs-float parity tests.
    kv_valid = lengths + 1

    # Pallas decode kernel path: blocked KV streaming, per-seq lengths
    # masking (incl. fused int8 dequant).  Rolling SWA caches keep the XLA
    # path (slot-validity masking is window-specific).
    if cfg.use_pallas_attention and cfg.sliding_window is None:
        from repro.kernels import ops as kernel_ops
        from repro.kernels.decode_attention import decode_attention_quant
        q1 = q[:, 0]  # (B, H, D)
        if cfg.kv_quant:
            interp = jax.default_backend() != "tpu"
            attn = decode_attention_quant(
                q1, new_cache["k"], new_cache["v"], new_cache["k_scale"],
                new_cache["v_scale"], kv_valid, interpret=interp)
        else:
            attn = kernel_ops.decode_attention(q1, new_k, new_v, kv_valid)
        out = attn[:, None].reshape(B, 1, cfg.num_heads * hd)
        proj = out @ params["wo"]
        return (proj, new_cache) if cfg.kv_quant else (proj, {"k": new_k, "v": new_v})

    qh = q.transpose(0, 2, 1, 3)  # (B, H, 1, D)
    kv_pos = jnp.arange(S)[None, :]  # slot index
    if cfg.sliding_window is not None:
        # slot s holds absolute position p iff p % S == s and p <= length;
        # valid iff within the last `window` positions.
        # absolute position held in slot s: the largest p <= lengths with p%S==s
        abs_pos = lengths[:, None] - ((lengths[:, None] - kv_pos) % S)
        valid = (abs_pos >= 0) & (abs_pos >= lengths[:, None] - (S - 1))
        mask = valid[:, None, None, :]  # (B,1,1,S)
    else:
        mask = (kv_pos < kv_valid[:, None])[:, None, None, :]
    out = _sdpa(qh, new_k, new_v, mask)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd)
    if cfg.kv_quant:
        return out @ params["wo"], new_cache
    return out @ params["wo"], {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# paged KV (block-table) serving paths — full attention only
# ---------------------------------------------------------------------------

def _paged_dims(cache: Dict[str, jax.Array]) -> Tuple[int, int]:
    """(num_blocks, block_size) of a page-pool cache layer."""
    return cache["k"].shape[0], cache["k"].shape[2]


def _write_pages(cfg, cache: Dict[str, jax.Array], k: jax.Array,
                 v: jax.Array, page: jax.Array,
                 offset: jax.Array) -> Dict[str, jax.Array]:
    """Scatter per-token k/v (..., KVH, D) into pages at (page, offset).

    ``page``/``offset`` index arrays share the leading dims of k/v; sentinel
    page ids (>= num_blocks) drop the write (inactive batch rows, logical
    blocks not yet allocated).
    """
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {
            "k": cache["k"].at[page, :, offset, :].set(kq, mode="drop"),
            "v": cache["v"].at[page, :, offset, :].set(vq, mode="drop"),
            "k_scale": cache["k_scale"].at[page, :, offset].set(ks, mode="drop"),
            "v_scale": cache["v_scale"].at[page, :, offset].set(vs, mode="drop"),
        }
    return {
        "k": cache["k"].at[page, :, offset, :].set(k, mode="drop"),
        "v": cache["v"].at[page, :, offset, :].set(v, mode="drop"),
    }


def _gather_dense_kv(cfg, cache: Dict[str, jax.Array], block_table: jax.Array,
                     dtype) -> Tuple[jax.Array, jax.Array]:
    """Densify a page pool through block tables -> (B, KVH, nb*bs, D) k/v
    (dequantized for int8 pools).  The XLA reference path on CPU; positions
    past each sequence's length hold garbage the caller must mask.

    k and v (and the scale pair on the quant path) ride ONE stacked gather
    each (``gather_kv_pages_fused``) — two gathers total instead of four
    for int8 pools, one instead of two for float."""
    from repro.kernels.paged_decode_attention import gather_kv_pages_fused
    k, v = gather_kv_pages_fused(cache["k"], cache["v"], block_table)
    if cfg.kv_quant:
        ks, vs = gather_kv_pages_fused(cache["k_scale"], cache["v_scale"],
                                       block_table)
        k = _dequantize_kv(k, ks, dtype)
        v = _dequantize_kv(v, vs, dtype)
    return k, v


def attend_decode_paged(params, cfg, x: jax.Array, lengths: jax.Array,
                        block_table: jax.Array,
                        cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against the paged KV pool.

    x: (B, 1, d); lengths: (B,) tokens already cached (= the new token's
    absolute position); block_table: (B, nb) physical page ids, sentinel
    entries >= num_blocks marking unallocated logical blocks; cache: page
    pool from ``init_paged_kv_cache``.  Requires full attention
    (``cfg.sliding_window is None`` — rolling-window paging is a ROADMAP
    follow-on).

    The new token's k/v is scattered into page ``block_table[b, pos // bs]``
    row ``pos % bs``; rows whose write page is unallocated (inactive slots,
    mid-prefill rows at a block boundary) drop the write via the sentinel.
    """
    B = x.shape[0]
    num_blocks, bs = _paged_dims(cache)
    nb = block_table.shape[1]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, lengths[:, None])
    k_new = k[:, 0]  # (B, KVH, D)
    v_new = v[:, 0]

    logical = lengths // bs
    offset = lengths % bs
    page = jnp.take_along_axis(
        block_table, jnp.minimum(logical, nb - 1)[:, None], axis=1)[:, 0]
    page = jnp.where(logical < nb, page, num_blocks)  # sentinel => dropped
    new_cache = _write_pages(cfg, cache, k_new, v_new, page, offset)

    # same inclusive convention as the dense path: the pool now holds
    # kv_valid tokens for each row, newest at logical position `lengths`
    kv_valid = lengths + 1
    q1 = q[:, 0]  # (B, H, D)
    if cfg.use_pallas_attention:
        from repro.kernels import ops as kernel_ops
        if cfg.kv_quant:
            attn = kernel_ops.paged_decode_attention_quant(
                q1, new_cache["k"], new_cache["v"], new_cache["k_scale"],
                new_cache["v_scale"], block_table, kv_valid,
                pages_per_tile=cfg.paged_pages_per_tile)
        else:
            attn = kernel_ops.paged_decode_attention(
                q1, new_cache["k"], new_cache["v"], block_table, kv_valid,
                pages_per_tile=cfg.paged_pages_per_tile)
    else:
        k_dense, v_dense = _gather_dense_kv(cfg, new_cache, block_table, x.dtype)
        mask = (jnp.arange(nb * bs)[None, :] < kv_valid[:, None])[:, None, None, :]
        attn = _sdpa(q.transpose(0, 2, 1, 3), k_dense, v_dense, mask)[:, :, 0]
    out = attn[:, None].reshape(B, 1, cfg.num_heads * hd)
    return out @ params["wo"], new_cache


def attend_prefill_chunk_paged(params, cfg, x: jax.Array,
                               positions: jax.Array, valid: jax.Array,
                               block_table: jax.Array,
                               cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunk-granular prefill continuation writing into the paged KV pool.

    Same contract as ``attend_prefill_chunk`` (x: (B, C, d) right-padded
    chunk, positions absolute, valid: (B,) real tokens per row, 0 =
    inactive) except the chunk's k/v scatter to (page, offset) pairs named
    by ``block_table`` instead of per-slot dense rows.  Full attention only.

    With ``cfg.use_pallas_attention`` the attention runs the flash-style
    paged prefill-chunk kernel: KV pages stream in place through the
    SMEM-prefetched block table and an online softmax folds the
    page-resident prefix with the causal in-chunk segment — per-chunk HBM
    reads proportional to live tokens, no densified copy.  The XLA
    fallback densifies the PRE-chunk pages with one stacked gather and
    appends the in-chunk keys, exactly mirroring the dense chunk path's
    two-segment masking (the CPU oracle the kernel is parity-tested
    against).

    Donation note: both the kernel and the gather fallback read the
    POST-write pool.  The chunk's page writes land at logical positions
    >= start while the prefix segment masks to positions < start, so the
    attended values are identical to a pre-write read — and the donated
    pool buffer's only consumer is the in-place scatter, so XLA updates
    the pages without copying the pool each chunk.
    """
    B, C, _ = x.shape
    num_blocks, bs = _paged_dims(cache)
    nb = block_table.shape[1]
    S = nb * bs
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, positions)  # k/v: (B, C, KVH, hd)
    starts = positions[:, 0]

    # ---- page writes: token (b, j) -> page bt[b, pos//bs], row pos%bs ----
    in_chunk = jnp.arange(C)[None, :] < valid[:, None]           # (B, C)
    logical = positions // bs
    offset = positions % bs
    page = jnp.take_along_axis(block_table, jnp.clip(logical, 0, nb - 1), axis=1)
    page = jnp.where(in_chunk & (logical < nb), page, num_blocks)
    new_cache = _write_pages(cfg, cache, k, v, page, offset)

    # ---- attention: [pre-chunk pages | in-chunk keys] --------------------
    qh = q.transpose(0, 2, 1, 3)                                 # (B, H, C, hd)
    kh = k.transpose(0, 2, 1, 3)                                 # (B, KVH, C, hd)
    vh = v.transpose(0, 2, 1, 3)
    starts_i = starts.astype(jnp.int32)
    valid_i = valid.astype(jnp.int32)

    if cfg.use_pallas_attention:
        # fused kernel: prefix pages stream in place from the POST-write
        # pool (rows >= start are masked — see the donation note above),
        # in-chunk k/v stay float
        from repro.kernels import ops as kernel_ops
        if cfg.kv_quant:
            attn = kernel_ops.paged_prefill_attention_quant(
                qh, new_cache["k"], new_cache["v"], new_cache["k_scale"],
                new_cache["v_scale"], kh, vh, block_table, starts_i, valid_i,
                pages_per_tile=cfg.paged_pages_per_tile)
        else:
            attn = kernel_ops.paged_prefill_attention(
                qh, new_cache["k"], new_cache["v"], kh, vh, block_table,
                starts_i, valid_i, pages_per_tile=cfg.paged_pages_per_tile)
        out = attn.transpose(0, 2, 1, 3).reshape(B, C, cfg.num_heads * hd)
        return out @ params["wo"], new_cache

    old_k, old_v = _gather_dense_kv(cfg, new_cache, block_table, x.dtype)
    k_all = jnp.concatenate([old_k, kh], axis=2)                 # (B, KVH, S+C, hd)
    v_all = jnp.concatenate([old_v, vh], axis=2)

    q_pos = positions[:, :, None]                                # (B, C, 1)
    s_idx = jnp.arange(S)[None, None, :]                         # (1, 1, S)
    cache_mask = jnp.broadcast_to(s_idx < starts[:, None, None], (B, C, S))
    j_idx = jnp.arange(C)[None, None, :]
    p_j = starts[:, None, None] + j_idx
    chunk_mask = (p_j <= q_pos) & (j_idx < valid[:, None, None])
    mask = jnp.concatenate([cache_mask, chunk_mask], axis=-1)[:, None]

    out = _sdpa(qh, k_all, v_all, mask)
    out = out.transpose(0, 2, 1, 3).reshape(B, C, cfg.num_heads * hd)
    return out @ params["wo"], new_cache


def attention_param_axes(cfg):
    """Logical sharding axes per leaf (mirrors init_attention)."""
    p = {
        "wq": ("embed", "heads_x_dim"),
        "wk": ("embed", "kv_heads_x_dim"),
        "wv": ("embed", "kv_heads_x_dim"),
        "wo": ("heads_x_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads_x_dim",)
        p["bk"] = ("kv_heads_x_dim",)
        p["bv"] = ("kv_heads_x_dim",)
    return p
