import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this builds the right step function —
``train_step`` (train_4k), ``prefill`` (prefill_32k), ``serve_step``
(decode_32k / long_500k: ONE token against a full-length cache) — from
ShapeDtypeStruct inputs (no allocation), lowers it under the production
mesh with explicit NamedShardings, compiles, and records:

  * ``memory_analysis()``  (per-device argument/output/temp bytes),
  * ``cost_analysis()``    (per-device HLO FLOPs / bytes accessed),
  * collective-traffic stats parsed from the optimized HLO,

into ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline
pass (benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (INPUT_SHAPES, ARCHITECTURES, get_arch, get_shape,
                           shape_applicable)
from repro.configs.base import InputShape, ModelConfig
from repro.distributed.sharding import (ShardingRules, batch_axes_tree,
                                        build_shardings)
from repro.launch.hlo_analysis import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models.model_factory import batch_struct, build_model
from repro.training.optimizer import AdamW, AdamWState
from repro.training.train_step import make_train_step

DTYPE = jnp.bfloat16
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def adapt_config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Hardware adaptation hooks (DESIGN.md §4): zamba2's shared attention
    runs sliding-window in long-context mode so the 500k cache stays
    bounded."""
    if shape.name == "long_500k" and cfg.arch_type == "hybrid" \
            and cfg.sliding_window is None:
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def input_specs(arch: str, shape_name: str, dtype=DTYPE) -> Dict[str, Any]:
    """Public: ShapeDtypeStruct stand-ins for every model input."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    cfg = adapt_config_for_shape(cfg, shape)
    return batch_struct(cfg, shape.global_batch, shape.seq_len, shape.kind, dtype)


# ---------------------------------------------------------------------------

def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh,
                    rules: Optional[ShardingRules] = None,
                    microbatches: int = 1, remat: bool = True):
    """Returns (jitted_fn, arg_structs, rules) ready to .lower()."""
    rules = rules or ShardingRules.default()
    cfg = adapt_config_for_shape(cfg, shape)
    model = build_model(cfg)
    params_struct = jax.eval_shape(lambda: model.init(jax.random.key(0), DTYPE))
    param_sh = build_shardings(mesh, params_struct, model.param_axes(), rules)
    data = batch_struct(cfg, shape.global_batch, shape.seq_len, shape.kind, DTYPE)
    data_sh = build_shardings(mesh, data, batch_axes_tree(data), rules)

    if shape.kind == "train":
        opt = AdamW(learning_rate=1e-4)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        opt_sh = AdamWState(
            step=build_shardings(mesh, opt_struct.step, (), rules),
            mu=param_sh, nu=param_sh)
        step_fn = make_train_step(model, opt, microbatches=microbatches,
                                  remat=remat)
        # out_shardings must match the donated inputs or XLA can't alias
        # the params/opt buffers (§Perf H1 'donate': −params−opt of peak).
        metrics_struct = jax.eval_shape(step_fn, params_struct, opt_struct, data)[2]
        from repro.distributed.sharding import replicated
        fn = jax.jit(step_fn, in_shardings=(param_sh, opt_sh, data_sh),
                     out_shardings=(param_sh, opt_sh,
                                    replicated(mesh, metrics_struct)),
                     donate_argnums=(0, 1))
        return fn, (params_struct, opt_struct, data), rules

    cache_struct = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, DTYPE))
    cache_sh = build_shardings(mesh, cache_struct, model.cache_axes(), rules)

    if shape.kind == "prefill":
        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache)
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, data_sh, cache_sh),
                     donate_argnums=(2,))
        return fn, (params_struct, data, cache_struct), rules

    assert shape.kind == "decode"
    def serve_step(params, cache, tokens, lengths):
        return model.decode_step(params, cache, tokens, lengths)
    tok_sh = build_shardings(mesh, data["tokens"], ("batch",), rules)
    len_sh = build_shardings(mesh, data["lengths"], ("batch",), rules)
    fn = jax.jit(serve_step, in_shardings=(param_sh, cache_sh, tok_sh, len_sh),
                 donate_argnums=(1,))
    return fn, (params_struct, cache_struct, data["tokens"], data["lengths"]), rules


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules: Optional[ShardingRules] = None, microbatches: int = 1,
            remat: bool = True, save: bool = True,
            tag: str = "", config_transform=None) -> Dict[str, Any]:
    cfg = get_arch(arch)
    if config_transform is not None:
        cfg = config_transform(cfg)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "applicable": shape_applicable(cfg, shape),
    }
    if not rec["applicable"]:
        rec["skip_reason"] = ("long_500k needs sub-quadratic decode; "
                              f"{arch} is full-attention (DESIGN.md §4)")
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    with mesh:  # eval_shape may trace with_sharding_constraint
        fn, args, rules = build_lowerable(cfg, shape, mesh, rules,
                                          microbatches=microbatches, remat=remat)
        lowered = fn.lower(*args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    n_chips = 512 if multi_pod else 256

    rec.update({
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        },
        "collectives": coll.to_dict(),
        "dropped_shardings": sorted(set(rules.dropped)),
        "model_params": cfg.param_count(),
        "model_active_params": cfg.active_param_count(),
        "tokens_per_step": shape.global_batch * (shape.seq_len if shape.kind == "train" else 1),
        "microbatches": microbatches,
    })
    if save:
        _save(rec)
    return rec


def _save(rec: Dict[str, Any]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        OUT_DIR, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = list(ARCHITECTURES) if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in INPUT_SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for a, s, mp in combos:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        path = os.path.join(OUT_DIR, f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {a} {s} {mesh_name} (exists)")
            continue
        try:
            rec = run_one(a, s, multi_pod=mp)
            if not rec["applicable"]:
                print(f"[n/a ] {a:24s} {s:12s} {mesh_name}: {rec['skip_reason']}")
                continue
            mem = rec["memory"]["peak_bytes_per_device"] / 2**30
            fl = rec["cost"]["flops_per_device"]
            cb = rec["collectives"]["total_bytes"]
            print(f"[ ok ] {a:24s} {s:12s} {mesh_name}: "
                  f"peak {mem:.2f} GiB/dev, {fl:.3g} flops/dev, "
                  f"{cb/2**20:.1f} MiB collectives, "
                  f"compile {rec['compile_s']:.0f}s")
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures += 1
            print(f"[FAIL] {a} {s} {mesh_name}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run combinations failed")
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
