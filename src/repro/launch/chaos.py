"""Chaos soak driver: seeded engine-failure injection against the live
QLM stack (the acceptance harness for §4 fault tolerance).

Runs N real JAX engines wrapped in ``serving.faults.FaultyEngine`` under
a seeded ``FaultPlan`` (default: kill one engine mid-decode), drives a
deterministic round loop on a VIRTUAL clock, and asserts the recovery
contract:

  * every submitted request reaches a terminal state (served, rejected,
    or failed-quarantined) — nothing strands;
  * BlockManager accounting is conserved on every engine INCLUDING the
    dead one (abandoned slots freed, snapshot pins released — zero
    leaked or pinned-forever blocks);
  * interactive SLO attainment stays above a floor despite the death;
  * the same seed replays the identical fault timeline
    (``--replay-check`` runs the soak twice and compares).

``--scenario`` selects the lifecycle under test:

  * ``kill`` (default) — hard mid-decode crash, the PR-7 contract above;
  * ``hang`` — the engine stalls silently (rounds "succeed" with zero
    progress, heartbeats keep flowing): the controller's round watchdog
    must detect it, with NO exception ever surfacing;
  * ``drain`` — graceful decommission: residents finish, the instance
    reaches DRAINED, zero evictions needed;
  * ``kill-replace`` — crash + ``ReplacementPolicy`` autoscaling: a
    fresh engine takes the dead slot and serves redelivered work;
  * ``migrate`` — forced drain-with-evict creates live-pinned KV
    snapshots that must resume token-identical on ANOTHER engine
    (cross-engine snapshot migration);
  * ``combined`` — hang one engine + crash another + replacement +
    ≥1 migration, outputs byte-identical to a no-fault baseline
    (the ISSUE-9 acceptance scenario; defaults to 3 instances);
  * ``none`` — fault-free baseline (used for output-identity checks).

``--plan-file`` overrides the scenario's fault schedule with a JSON
``FaultPlan`` (``FaultPlan.from_json``) for replaying captured
timelines.

``--no-supervision`` runs the same fault schedule with the recovery
machinery disabled (failures swallowed, no redelivery): requests strand,
proving the harness detects exactly what the supervision layer fixes.

Run it under ``QLINT_INVARIANTS=1`` so every engine round and controller
tick double-checks the block/queue/terminal-state invariants:

  PYTHONPATH=src QLINT_INVARIANTS=1 python -m repro.launch.chaos \
      --replay-check --json CHAOS_stats.json --timeline CHAOS_timeline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import numpy as np

from repro.analysis.invariants import (check_block_manager, check_migration,
                                       check_queue_layer,
                                       check_terminal_states)
from repro.configs import get_arch
from repro.core.autoscale import ReplacementPolicy
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           EngineFailure, FaultPlan, FaultSpec, FaultyEngine)


class VirtualClock:
    """Deterministic time source: the round loop advances it explicitly,
    so timelines, backoff windows, and TTFTs are replayable bit-for-bit
    (wall time would smear the fault schedule across runs)."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _hw(max_new: int, tier: Optional[int] = None) -> HardwareProfile:
    # static profile (no calibration pass): the soak measures recovery
    # behavior, not scheduling quality, and static costs keep it seeded.
    # --hetero assigns instance i the fast/mid/slow tier (i % 3) so the
    # scheduler's drain/swap estimates differ per instance.  The spread
    # is deliberately mild (2x end to end): every staged fault needs its
    # target engine to carry real work (a starved engine neither stalls
    # visibly, nor decodes enough to reach its crash occurrence, nor
    # holds sharers to migrate on drain), and a steeper spread lets the
    # solver serve the whole soak workload from the fastest tier alone.
    scale = 1.0 if tier is None else (0.75, 1.0, 1.5)[tier % 3]
    return HardwareProfile(prefill_time=0.05 * scale,
                           decode_per_token=0.02 * scale,
                           inefficiency=1.2,
                           token_capacity=int(512 / scale),
                           swap_time=0.2, model_max_tokens=max(64, max_new))


def default_plan(args) -> FaultPlan:
    scenario = getattr(args, "scenario", "kill")
    plan_file = getattr(args, "plan_file", None)
    if plan_file:
        with open(plan_file) as f:
            return FaultPlan.from_json(f.read())
    specs = []
    if scenario in ("kill", "kill-replace", "combined"):
        specs.append(FaultSpec(site=args.site, kind="crash",
                               engine=args.kill_engine, at_count=args.kill_at))
    if scenario in ("hang", "combined"):
        # hang fires on the round site so it stalls the engine even while
        # it is only pulling work (no decode occurrences needed)
        specs.append(FaultSpec(site="round", kind="hang",
                               engine=getattr(args, "hang_engine", 0),
                               at_count=getattr(args, "hang_at", 6)))
    if args.error_prob > 0:
        # probabilistic transient errors on the surviving engine exercise
        # the strike/heartbeat-recovery path alongside the hard kill
        specs.append(FaultSpec(site="round", kind="error", engine=None,
                               prob=args.error_prob, max_fires=2))
    return FaultPlan(specs, seed=args.seed)


def build_cluster(args, plan: FaultPlan):
    import jax
    import time as _time
    cfg = get_arch(args.arch).reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    registry = {args.arch: (model, params)}
    threaded = bool(getattr(args, "threaded", False))
    hetero = bool(getattr(args, "hetero", False))
    # threaded mode runs on real wall time (concurrent rounds cannot share
    # a manually-advanced clock); the seeded round-robin loop keeps the
    # virtual clock so timelines replay bit-for-bit
    clock = _time.monotonic if threaded else VirtualClock()
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128, block_size=8,
                        attention_backend="paged-xla", prefix_sharing=True)

    def make_engine(engine_id: int) -> FaultyEngine:
        # replacement engines get FRESH unique ids so the plan's
        # occurrence counters never re-fire on the new hardware
        inner = ContinuousBatchingEngine(model, params, ecfg,
                                         model_name=args.arch, clock=clock)
        return FaultyEngine(inner, plan, engine_id=engine_id)

    engines, agents, infos = [], [], []
    for i in range(args.instances):
        eng = make_engine(i)
        vq = VirtualQueue(i)
        agents.append(QLMAgent(eng, vq, registry))
        engines.append(eng)
        hw = _hw(args.max_new_tokens, tier=i if hetero else None)
        infos.append(InstanceInfo(i, {args.arch: hw}, args.arch, vq))
    scenario = getattr(args, "scenario", "kill")
    grace = getattr(args, "hang_grace", None)
    if grace is None and scenario in ("hang", "combined"):
        # threaded rounds run on wall time, where a first-shape XLA
        # compile stalls a HEALTHY busy engine for several seconds — a
        # pause the virtual clock never sees.  The wider grace keeps the
        # watchdog from false-killing a compiling engine while still
        # catching the injected hang well inside the soak wall budget.
        grace = 10.0 if threaded else 3.0
    controller = QLMController(infos, QLMConfig(
        avg_batch_size=args.slots, reschedule_cooldown=0.5,
        retry_budget=args.retry_budget, backoff_base_s=0.05,
        backoff_cap_s=1.0, hang_grace_rounds=grace,
        routing=getattr(args, "routing", "solver")))
    controller.attach_engines(engines)
    return clock, engines, agents, controller, make_engine, registry


def build_requests(args) -> List:
    rng = np.random.default_rng(args.seed)
    classes = ["interactive", "interactive", "batch1"]
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    # migration scenarios prepend a shared system-prompt-style prefix:
    # prefix sharing turns it into pinned pages, and pinned pages are what
    # eviction leaves behind / migration must materialize away
    shared = getattr(args, "shared_prefix", None)
    if shared is None:
        shared = 8 if getattr(args, "scenario", "kill") in ("migrate",
                                                            "combined") else 0
    prefix = list(range(1, int(shared) + 1))
    reqs = []
    for i in range(args.requests):
        tail = rng.integers(0, 100, size=int(rng.integers(6, 20))).tolist()
        reqs.append(make_request(prefix + tail, args.arch,
                                 classes[i % len(classes)],
                                 arrival_time=float(arrivals[i]),
                                 max_new_tokens=args.max_new_tokens))
    return reqs


def _terminal(r) -> bool:
    return r.finished() or r.dropped()


def run_soak(args, plan: Optional[FaultPlan] = None) -> dict:
    """One soak run.  Returns the stats dict (pure data — the CLI's
    assertions live in main() so tests can call this directly).
    Dispatches to the threaded wall-clock loop under --threaded."""
    if getattr(args, "threaded", False):
        return run_soak_threaded(args, plan)
    return _run_soak_round_robin(args, plan)


def _run_soak_round_robin(args, plan: Optional[FaultPlan] = None) -> dict:
    """The seeded virtual-clock round-robin loop (replayable timelines)."""
    plan = default_plan(args) if plan is None else plan
    scenario = getattr(args, "scenario", "kill")
    clock, engines, agents, controller, make_engine, registry = \
        build_cluster(args, plan)
    reqs = build_requests(args)
    pending = list(reqs)

    policy = None
    if scenario in ("kill-replace", "combined"):
        policy = ReplacementPolicy(
            cooldown_s=getattr(args, "replace_cooldown", 0.5))
    drain_engine = getattr(args, "drain_engine", None)
    if drain_engine is None:
        # combined drains the engine that neither hangs nor crashes
        drain_engine = args.instances - 1 if scenario == "combined" else 0
    drain_round = getattr(args, "drain_at_round", None)
    if drain_round is None:
        # migration scenarios drain while sharers are still co-resident
        # (pins only exist while ≥2 sequences reference the prefix pages)
        drain_round = {"migrate": 16, "combined": 8}.get(scenario, 40)
    # migration scenarios evict on drain so live-pinned snapshots exist
    # and MUST move; plain drain is graceful (zero evictions)
    drain_evict = bool(getattr(args, "drain_evict", False)) \
        or scenario in ("migrate", "combined")
    drains_scenario = scenario in ("drain", "migrate", "combined")
    drained_fired = False
    retired: List[tuple] = []
    next_engine_id = args.instances

    supervision = not args.no_supervision
    rounds = failures = 0
    while rounds < args.max_rounds:
        rounds += 1
        now = clock.advance(args.round_dt)
        while pending and pending[0].arrival_time <= now:
            controller.submit(pending.pop(0), now)
        if (drains_scenario and not drained_fired and rounds >= drain_round
                and controller.is_schedulable(drain_engine)):
            # an evicting drain only migrates anything if the instance is
            # busy when it lands, so wait for ≥2 co-resident sharers
            # (bounded: past 4x the trigger round, drain regardless)
            busy = getattr(engines[drain_engine], "num_active", lambda: 0)()
            if not drain_evict or busy >= 2 or rounds >= 4 * drain_round:
                controller.drain_instance(drain_engine, now,
                                          evict=drain_evict,
                                          cause=f"chaos scenario={scenario}")
                drained_fired = True
        controller.tick(now)
        if policy is not None and supervision:
            for idx in policy.replacements_due(controller, now):
                eng = make_engine(next_engine_id)
                next_engine_id += 1
                retired.append((idx, engines[idx]))
                controller.replace_instance(idx, eng, now)
                engines[idx] = eng
                agents[idx] = QLMAgent(
                    eng, controller.instances[idx].virtual_queue, registry)
        for idx, agent in enumerate(agents):
            if not controller.is_alive(idx):
                continue
            if not supervision and agent.engine.dead:
                continue   # unsupervised: the controller never learns
            try:
                agent.run_iteration()
            except EngineFailure as e:
                failures += 1
                if supervision:
                    controller.report_engine_failure(idx, e, now,
                                                     engine=agent.engine)
                    agent.reset()
            else:
                if supervision:
                    controller.heartbeat(idx, now)
        if not pending and all(_terminal(r) for r in reqs) \
                and not any(h.state == "draining" for h in controller.health):
            break

    return _finalize(args, plan, clock(), controller, engines, retired,
                     reqs, rounds, failures, supervision)


def run_soak_threaded(args, plan: Optional[FaultPlan] = None) -> dict:
    """Thread-per-engine soak: same fault schedule, real wall-clock
    concurrency (``serving.cluster.ThreadedCluster``).

    Occurrence-counted faults still fire deterministically PER ENGINE
    (each engine's round/decode counters are thread-local sequences), but
    cross-engine event ordering and timestamps are wall-clock — so the
    lifecycle triggers are work-based here (drain when the target is
    busy, wall-time fallback) instead of round-indexed, and
    ``--replay-check`` is a round-robin-only contract.
    """
    import time as _time
    from repro.serving import ThreadedCluster

    plan = default_plan(args) if plan is None else plan
    scenario = getattr(args, "scenario", "kill")
    if args.no_supervision:
        raise SystemExit("--no-supervision is a round-robin-only harness "
                         "mode (the threaded loop IS the supervision)")
    clock, engines, agents, controller, make_engine, registry = \
        build_cluster(args, plan)
    reqs = build_requests(args)
    t0 = _time.monotonic()
    for r in reqs:
        r.arrival_time += t0          # virtual offsets -> wall schedule
    pending = list(reqs)

    policy = None
    if scenario in ("kill-replace", "combined"):
        policy = ReplacementPolicy(
            cooldown_s=getattr(args, "replace_cooldown", 0.5))
    # drain target: an explicit --drain-engine pins it; otherwise the
    # threaded loop picks DYNAMICALLY — the first engine observed holding
    # residents when the drain is due.  Wall-clock placement is not
    # replayable, so a fixed index routinely names an engine the solver
    # happens to starve (e.g. the slow hetero tier), and an evicting
    # drain on an empty engine migrates nothing.
    drain_engine = getattr(args, "drain_engine", None)
    drain_evict = bool(getattr(args, "drain_evict", False)) \
        or scenario in ("migrate", "combined")
    drains_scenario = scenario in ("drain", "migrate", "combined")
    drained_fired = False
    retired: List[tuple] = []
    next_engine_id = args.instances
    max_wall = getattr(args, "max_wall", 60.0)
    deadline = t0 + max_wall

    # sustain traffic THROUGH the drain: hold the tail of the workload
    # back until the drain is armed so the evicted/pinned state has live
    # siblings to migrate toward (released unconditionally at 0.4·wall so
    # a never-arming drain cannot strand them)
    holdback: List = []
    if drains_scenario:
        k = max(1, len(pending) // 4)
        holdback, pending = pending[-k:], pending[:-k]

    cluster = ThreadedCluster(controller, agents, engines)

    def _drain_armed() -> bool:
        """combined stages its phases: the drain waits until the hang has
        been detected AND the crash has fired, so the drain cannot land
        on (and retire) an engine whose staged fault hasn't hit yet."""
        if scenario != "combined":
            return True
        return controller.hangs >= 1 and sum(cluster.failures) >= 1

    # round-granular drain trigger, run on each agent's OWN thread
    # between rounds: a 10ms polling loop reliably misses the instants
    # when an engine holds residents, but between-rounds observation
    # cannot.  An evicting drain wants >= 2 co-residents (pins — and thus
    # pinned-snapshot migration — only exist while sharers overlap).
    need_busy = 2 if drain_evict else 1

    def _drain_hook(idx: int) -> None:
        nonlocal drained_fired, drain_engine
        if drained_fired or not _drain_armed():
            return
        if drain_engine is not None and idx != drain_engine:
            return
        eng = cluster.engines[idx]
        with eng.lock:   # own agent thread, between rounds: free
            if getattr(eng, "num_active", lambda: 0)() < need_busy:
                return
            if drain_evict:
                # only sequences whose leading blocks are SHARED
                # (refcount > 1) leave pinned snapshots behind on evict;
                # two non-sharing residents (e.g. both resumed from
                # snapshots) would drain without exercising migration
                bm = getattr(eng, "block_mgr", None)
                if bm is None or not any(bm.shared_prefix_len(sid) > 0
                                         for sid in list(bm._seqs)):
                    return
            with controller.lock:
                if drained_fired or not controller.is_schedulable(idx):
                    return
                controller.drain_instance(
                    idx, _time.monotonic(), evict=drain_evict,
                    cause=f"chaos scenario={scenario} (threaded)")
                drained_fired = True
                drain_engine = idx

    if drains_scenario:
        cluster.round_hook = _drain_hook
    cluster.start()
    try:
        while _time.monotonic() < deadline:
            now = _time.monotonic()
            if holdback and (_drain_armed() or drained_fired
                             or now - t0 > 0.4 * max_wall):
                for r in holdback:
                    # re-anchor deadlines: the tranche was gated by the
                    # harness, not queued, so its SLO clock starts now
                    r.arrival_time = max(r.arrival_time, now)
                pending.extend(holdback)
                holdback = []
            while pending and pending[0].arrival_time <= now:
                controller.submit(pending.pop(0), now)
            if (drains_scenario and not drained_fired
                    and now - t0 > 0.5 * max_wall):
                # wall fallback so a starved cluster still drains before
                # the loop gives up (the round hook is the real trigger)
                cands = [drain_engine] if drain_engine is not None \
                    else list(range(len(cluster.engines)))
                for idx in cands:
                    if controller.is_schedulable(idx):
                        controller.drain_instance(
                            idx, now, evict=drain_evict,
                            cause=f"chaos scenario={scenario} "
                                  f"(threaded, fallback)")
                        drained_fired = True
                        drain_engine = idx
                        break
            if policy is not None:
                with controller.lock:
                    due = policy.replacements_due(controller, now)
                for idx in due:
                    eng = make_engine(next_engine_id)
                    next_engine_id += 1
                    retired.append((idx, cluster.engines[idx]))
                    cluster.replace(
                        idx, eng,
                        QLMAgent(eng,
                                 controller.instances[idx].virtual_queue,
                                 registry), now)
            if not pending and not holdback \
                    and all(_terminal(r) for r in reqs) \
                    and not any(h.state == "draining"
                                for h in controller.health):
                break
            _time.sleep(0.01)
    finally:
        cluster.stop()
    return _finalize(args, plan, _time.monotonic(), controller,
                     cluster.engines, retired, reqs, sum(cluster.rounds),
                     sum(cluster.failures), supervision=True)


def _finalize(args, plan, now, controller, engines, retired, reqs,
              rounds, failures, supervision) -> dict:
    """End-state invariants + the stats dict (shared by both loops)."""
    scenario = getattr(args, "scenario", "kill")
    controller.gc_groups()
    # end-state invariants (always on here, env var or not): conservation
    # must hold on EVERY pool — the dead engine's accounting was salvaged
    # host-side, so it conserves too
    leaked = []
    for idx, eng in enumerate(engines):
        bm = eng.block_mgr
        check_block_manager(bm, where=f"chaos/engine{idx}")
        leaked.extend(f"engine{idx}:seq{sid}" for sid in bm._seqs
                      if controller.is_alive(idx) or supervision)
        leaked.extend(f"engine{idx}:pin{b}" for b, p in bm._pins.items()
                      if p > 0)
    for j, (idx, eng) in enumerate(retired):
        # replaced (dead/drained) engines: salvage + migration must have
        # emptied the pool — retired capacity may hold nobody's state
        bm = eng.block_mgr
        check_block_manager(bm, where=f"chaos/retired{j}(was engine{idx})")
        leaked.extend(f"retired{j}:seq{sid}" for sid in bm._seqs)
        leaked.extend(f"retired{j}:pin{b}" for b, p in bm._pins.items()
                      if p > 0)
    if supervision:
        check_queue_layer(controller, where="chaos/end")
        check_terminal_states(controller, engines=engines, where="chaos/end")
        check_migration(controller, engines=engines, where="chaos/end")

    stranded = [r for r in reqs if not _terminal(r)]
    interactive = [r for r in reqs if r.slo_class == "interactive"]
    inter_hits = sum(1 for r in interactive
                     if not r.failed and r.slo_met() is True)
    stats = {
        "seed": args.seed,
        "scenario": scenario,
        "supervision": supervision,
        "threaded": bool(getattr(args, "threaded", False)),
        "hetero": bool(getattr(args, "hetero", False)),
        "routing": controller.cfg.routing,
        "rounds": rounds,
        "requests": len(reqs),
        "served": sum(1 for r in reqs if r.finished() and not r.failed
                      and not r.rejected),
        "failed_quarantined": len(controller.failed),
        "rejected": len(controller.rejected),
        "stranded": len(stranded),
        "redeliveries": controller.redeliveries,
        "engine_failures": failures,
        "hangs": getattr(controller, "hangs", 0),
        "drains": getattr(controller, "drains", 0),
        "replacements": getattr(controller, "replacements", 0),
        "migrations": getattr(controller, "migrations", 0),
        "dead_instances": [i for i in range(len(engines))
                           if not controller.is_alive(i)],
        "health": [h.state for h in controller.health],
        "leaked_blocks": leaked,
        "slo_attainment": controller.slo_attainment(now),
        "interactive_attainment": (inter_hits / len(interactive)
                                   if interactive else 1.0),
        "timeline": plan.timeline(),
        # keyed by build-order index (req_id is a process-global counter,
        # so it differs across runs in one process); used for the
        # token-identity check against the no-fault baseline
        "outputs": {str(i): list(r.output_tokens) for i, r in enumerate(reqs)
                    if r.finished() and not r.failed and not r.rejected},
    }
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--instances", type=int, default=None,
                    help="engine count (default 2; 3 for combined, which "
                         "stages faults on three distinct engines)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="kill",
                    choices=["kill", "hang", "drain", "kill-replace",
                             "migrate", "combined", "none"],
                    help="lifecycle under test (see module docstring)")
    ap.add_argument("--plan-file", dest="plan_file", default=None,
                    help="JSON FaultPlan overriding the scenario's fault "
                         "schedule (FaultPlan.from_json)")
    ap.add_argument("--site", default="decode",
                    choices=["decode", "prefill", "swap", "materialize",
                             "round"])
    ap.add_argument("--kill-engine", type=int, default=1)
    ap.add_argument("--kill-at", type=int, default=4,
                    help="kill at the Nth occurrence of --site on "
                         "--kill-engine (occurrence counts, not wall "
                         "time: that is what makes the timeline seeded)")
    ap.add_argument("--error-prob", type=float, default=0.0,
                    help="per-round transient-error probability (strikes)")
    ap.add_argument("--hang-engine", type=int, default=0,
                    help="engine stalled by the hang/combined scenarios")
    ap.add_argument("--hang-at", type=int, default=6,
                    help="hang at the Nth round occurrence on --hang-engine")
    ap.add_argument("--hang-grace", type=float, default=None,
                    help="watchdog grace in calibrated round deadlines "
                         "(default for hang scenarios: 3.0, or 10.0 "
                         "threaded — wall-clock XLA compiles stall "
                         "healthy engines; else off)")
    ap.add_argument("--drain-engine", type=int, default=None,
                    help="instance drained by drain/migrate/combined "
                         "(round-robin default: 0, or the last instance "
                         "for combined; threaded default: dynamic — the "
                         "first engine observed holding residents)")
    ap.add_argument("--drain-at-round", type=int, default=None,
                    help="round at which the drain LSO fires (default 40, "
                         "or 16 for migrate/combined so sharers are still "
                         "co-resident when the evict lands)")
    ap.add_argument("--drain-evict", action="store_true",
                    help="drain with forced eviction (migrate/combined "
                         "imply this: it is what creates migratable pins)")
    ap.add_argument("--replace-cooldown", type=float, default=0.5,
                    help="ReplacementPolicy decision cooldown, virtual s")
    ap.add_argument("--shared-prefix", type=int, default=None,
                    help="shared leading prompt tokens (default: 8 for "
                         "migrate/combined — sharing is what creates "
                         "migratable pins — else 0)")
    ap.add_argument("--retry-budget", type=int, default=2)
    ap.add_argument("--round-dt", type=float, default=0.05,
                    help="virtual seconds per round")
    ap.add_argument("--max-rounds", type=int, default=3000)
    ap.add_argument("--threaded", action="store_true",
                    help="thread-per-engine wall-clock loop "
                         "(ThreadedCluster) instead of the seeded "
                         "virtual-clock round-robin")
    ap.add_argument("--hetero", action="store_true",
                    help="heterogeneous static profiles: instance i gets "
                         "the fast/mid/slow tier (i %% 3)")
    ap.add_argument("--routing", default="solver",
                    choices=["solver", "slice"],
                    help="group placement policy (core/routing.py)")
    ap.add_argument("--max-wall", type=float, default=60.0,
                    help="wall-clock bound for the threaded loop")
    ap.add_argument("--attainment-floor", type=float, default=0.5,
                    help="minimum interactive attainment despite the kill")
    ap.add_argument("--no-supervision", action="store_true",
                    help="faults on, recovery off: assert requests STRAND "
                         "(the harness detects what the machinery fixes)")
    ap.add_argument("--replay-check", action="store_true",
                    help="run twice from the same seed and require "
                         "identical fault timelines")
    ap.add_argument("--json", default=None, help="write final stats JSON")
    ap.add_argument("--timeline", default=None,
                    help="write the fault timeline JSON")
    args = ap.parse_args(argv)
    if args.instances is None:
        args.instances = 3 if args.scenario == "combined" else 2
    if args.threaded and args.replay_check:
        ap.error("--replay-check needs the seeded round-robin loop "
                 "(threaded wall-clock ordering is not replayable)")

    stats = run_soak(args)
    scenario = args.scenario
    failures: List[str] = []
    if args.no_supervision:
        if stats["stranded"] == 0:
            failures.append(
                "no-supervision run stranded nothing: the fault plan "
                "never hit live work (harness bug)")
    else:
        if stats["stranded"]:
            failures.append(f"{stats['stranded']} request(s) stranded "
                            f"non-terminal")
        if stats["leaked_blocks"]:
            failures.append(f"leaked KV accounting: {stats['leaked_blocks']}")
        if scenario == "kill" and not stats["dead_instances"]:
            failures.append("fault plan killed no engine (kill-at never "
                            "reached: raise --requests or lower --kill-at)")
        if scenario in ("kill-replace", "combined"):
            if stats["engine_failures"] < 1:
                failures.append("crash never fired (kill-at never reached)")
            if stats["replacements"] < 1:
                failures.append("ReplacementPolicy never replaced the dead "
                                "capacity")
        if scenario in ("hang", "combined") and stats["hangs"] < 1:
            failures.append("round watchdog never detected the hang "
                            "(no-exception stall went unnoticed)")
        if scenario in ("drain", "migrate", "combined") \
                and stats["drains"] < 1:
            failures.append("drain LSO never fired")
        if scenario in ("drain", "migrate") \
                and "drained" not in stats["health"]:
            failures.append(f"drain never completed: health "
                            f"{stats['health']}")
        if scenario in ("migrate", "combined") and stats["migrations"] < 1:
            failures.append("no snapshot migrated cross-engine (drain-evict "
                            "produced no live pins?)")
        if stats["interactive_attainment"] < args.attainment_floor:
            failures.append(
                f"interactive attainment {stats['interactive_attainment']:.3f}"
                f" below floor {args.attainment_floor}")
        if scenario in ("migrate", "combined"):
            # migrated (and every other served) request must be
            # token-identical to the same-seed run with no faults at all
            base_args = argparse.Namespace(**vars(args))
            if base_args.shared_prefix is None:
                base_args.shared_prefix = 8   # the migrate-scenario default
            base_args.scenario, base_args.plan_file = "none", None
            base = run_soak(base_args, plan=FaultPlan([], seed=args.seed))
            common = set(stats["outputs"]) & set(base["outputs"])
            if not common:
                failures.append("no served request overlaps the no-fault "
                                "baseline (nothing to token-compare)")
            diverged = sorted(int(i) for i in common
                              if stats["outputs"][i] != base["outputs"][i])
            if diverged:
                failures.append(f"outputs diverged from the no-fault "
                                f"baseline for request(s) {diverged}: "
                                f"migration is not token-preserving")
            else:
                stats["outputs_match_baseline"] = len(common)
        if args.replay_check:
            replay = run_soak(args)
            if replay["timeline"] != stats["timeline"]:
                failures.append(
                    f"replay diverged: {stats['timeline']} vs "
                    f"{replay['timeline']}")
            elif replay["outputs"] != stats["outputs"]:
                failures.append("replay produced different tokens from "
                                "the same seed")
            else:
                stats["replay_identical"] = True

    if args.json:
        with open(args.json, "w") as f:
            json.dump(stats, f, indent=2)
    if args.timeline:
        with open(args.timeline, "w") as f:
            json.dump({"seed": args.seed, "events": stats["timeline"]}, f,
                      indent=2)
    for k, v in stats.items():
        if k not in ("timeline", "outputs"):
            print(f"{k:24s} {v:.3f}" if isinstance(v, float)
                  else f"{k:24s} {v}")
    for msg in failures:
        print(f"CHAOS FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
