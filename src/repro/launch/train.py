"""Training driver: ``--arch <id>`` end-to-end LM training.

On CPU this runs reduced configs (``--reduced``, default) — the same code
path pjit-compiles for the production mesh on TPU (``--mesh prod``).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.models.model_factory import materialize_batch
from repro.training import (AdamW, SyntheticLMDataset, cosine_schedule,
                            make_train_step, save_checkpoint)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=args.layers, d_model=args.d_model)
    model = build_model(cfg)

    key = jax.random.key(args.seed)
    params = model.init(key)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"({cfg.arch_type}, {cfg.num_layers}L d={cfg.d_model})")

    opt = AdamW(learning_rate=cosine_schedule(args.lr, args.steps // 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=args.microbatches))

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    it = iter(ds)
    extras_key = jax.random.key(args.seed + 1)

    losses = []
    t0 = time.monotonic()
    for step in range(args.steps):
        batch = dict(next(it))
        # modality stubs (VLM patches / audio frames) ride along
        mat = materialize_batch(cfg, args.batch, args.seq, "train", extras_key)
        for k, v in mat.items():
            if k != "tokens":
                batch[k] = v
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.monotonic() - t0
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} tok/s {tok_s:.0f}")
        assert np.isfinite(loss), f"loss diverged at step {step}"

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt_state, args.steps,
                        {"arch": cfg.name})
        print(f"checkpoint -> {args.checkpoint}")
    result = {"first_loss": losses[0], "last_loss": losses[-1],
              "min_loss": min(losses)}
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(improved {losses[0]-losses[-1]:.4f})")
    return result


if __name__ == "__main__":
    main()
