"""Production meshes.

NOTE: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; see ``dryrun.py``).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (v5e); 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires forced host devices if > 1)."""
    return jax.make_mesh((data, model), ("data", "model"))
