import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: tagged dry-run variants for the three chosen
(arch × shape) pairs, each with an explicit hypothesis (see EXPERIMENTS.md
§Perf for the full hypothesis → change → before/after → verdict log).

  PYTHONPATH=src python -m repro.launch.hillclimb --target granite-decode
"""
import argparse
import dataclasses
import json

from repro.launch import dryrun


def _report(rec):
    from benchmarks.roofline import analyze
    a = analyze(rec, correct=False)  # raw terms: consistent A/B within a pair
    return (f"tag={rec['tag'] or 'baseline':14s} "
            f"compute={a['compute_s']*1e3:9.2f}ms memory={a['memory_s']*1e3:9.2f}ms "
            f"coll={a['collective_s']*1e3:9.2f}ms dominant={a['dominant']:10s} "
            f"peak={a['peak_gib_per_device']:7.2f}GiB")


# ---------------------------------------------------------------------------
# variants per target
# ---------------------------------------------------------------------------

def granite_decode():
    """H3: decode is memory-bound (KV cache streaming).  Changes:
    pet   — bf16 matmul operands w/ f32 accumulation (no f32 cache copies);
            [applied in attention._sdpa — the live code IS the variant]
    """
    yield dict(tag="pet")  # current code (post-_sdpa change)
    # iteration 2: int8 KV cache (per-row scales) — halves resident cache
    # bytes; Pallas decode kernel dequantizes in VMEM on TPU.
    yield dict(tag="kvquant8",
               config_transform=lambda c: dataclasses.replace(c, kv_quant=True))


def deepseek_train():
    """H1: memory-bound at 362 GiB/dev; peak = full (L,L) f32 scores + remat
    residuals.  Changes:
    mb8       — 8 microbatches: activation batch 16→2 per ubatch;
    chunk512  — q-chunked attention: scores (L,L)→(512,L);
    mb8+chunk — both;
    +seqshard — also shard residual seq dim over 'model'.
    """
    yield dict(tag="mb8", microbatches=8)
    yield dict(tag="chunk512",
               config_transform=lambda c: dataclasses.replace(c, train_attn_chunk=512))
    yield dict(tag="mb8_chunk512", microbatches=8,
               config_transform=lambda c: dataclasses.replace(c, train_attn_chunk=512))
    yield dict(tag="mb8_chunk512_seqshard", microbatches=8,
               config_transform=lambda c: dataclasses.replace(
                   c, train_attn_chunk=512, shard_activations_seq=True))
    # iteration 2 (after measuring the above): donation aliasing + FSDP
    yield dict(tag="seqshard_donate",
               config_transform=lambda c: dataclasses.replace(
                   c, train_attn_chunk=512, shard_activations_seq=True))
    yield dict(tag="seqshard_donate_fsdp",
               rules_overrides={"embed": "data"},
               config_transform=lambda c: dataclasses.replace(
                   c, train_attn_chunk=512, shard_activations_seq=True))
    # iteration 3: fix f32 update promotion (donation now aliases) and try
    # 2-D weight sharding on the WIDE dim only (ff/heads over data×model)
    # instead of the embed-dim FSDP that exploded in iteration 2.
    yield dict(tag="seqshard_dtype",
               config_transform=lambda c: dataclasses.replace(
                   c, train_attn_chunk=512, shard_activations_seq=True))
    yield dict(tag="seqshard_dtype_wide2d",
               rules_overrides={"ff": ("data", "model"),
                                "heads_x_dim": ("data", "model"),
                                "kv_heads_x_dim": ("data", "model"),
                                "vocab": ("data", "model")},
               config_transform=lambda c: dataclasses.replace(
                   c, train_attn_chunk=512, shard_activations_seq=True))


def qwen3_train():
    """H2: collective-bound at 3.87 s (all-gather 132 GiB/dev from the MoE
    scatter).  Changes:
    g16        — dispatch_groups=16 (data-axis-aligned shard-local scatter);
    g16+mb4    — plus microbatching (also shrinks dispatch working set).
    """
    def set_groups(c, g, **kw):
        return dataclasses.replace(c, moe=dataclasses.replace(c.moe, dispatch_groups=g), **kw)
    yield dict(tag="g16", config_transform=lambda c: set_groups(c, 16))
    yield dict(tag="g16_mb4", microbatches=4,
               config_transform=lambda c: set_groups(c, 16))
    # iteration 2: + donation aliasing + seq-sharded activations
    yield dict(tag="g16_mb4_seqshard_donate", microbatches=4,
               config_transform=lambda c: set_groups(c, 16, shard_activations_seq=True))


TARGETS = {
    "granite-decode": ("granite-3-2b", "decode_32k", granite_decode),
    "deepseek-train": ("deepseek-67b", "train_4k", deepseek_train),
    "qwen3-train": ("qwen3-moe-30b-a3b", "train_4k", qwen3_train),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", choices=sorted(TARGETS) + ["all"], default="all")
    args = ap.parse_args()
    targets = sorted(TARGETS) if args.target == "all" else [args.target]
    for t in targets:
        arch, shape, gen = TARGETS[t]
        print(f"=== {t}: {arch} × {shape} ===")
        base_path = os.path.join(dryrun.OUT_DIR, f"{arch}__{shape}__pod16x16.json")
        if os.path.exists(base_path):
            with open(base_path) as f:
                print("  " + _report(json.load(f)) + "   <- paper-faithful baseline")
        for variant in gen():
            tag = variant.pop("tag")
            done = os.path.join(dryrun.OUT_DIR,
                                f"{arch}__{shape}__pod16x16__{tag}.json")
            if os.path.exists(done):
                with open(done) as f:
                    print("  " + _report(json.load(f)) + "   (cached)", flush=True)
                continue
            overrides = variant.pop("rules_overrides", None)
            if overrides:
                from repro.distributed.sharding import ShardingRules
                variant["rules"] = ShardingRules.default(overrides)
            rec = dryrun.run_one(arch, shape, tag=tag, **variant)
            print("  " + _report(rec), flush=True)


if __name__ == "__main__":
    main()
