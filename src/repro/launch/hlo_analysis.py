"""HLO text analysis: collective-traffic extraction for the roofline.

``cost_analysis()`` does not report collective bytes, so we parse the
optimized HLO (``compiled.as_text()``) and sum the result-shape sizes of
every collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute).  Result-shape bytes are the right roofline proxy: for
all-gather it is the full gathered tensor each device materializes; for
all-reduce the reduced tensor (ring traffic ≈ 2× but we keep the consistent
lower bound and note it); replica-group size scales per-link traffic and is
reflected through the ``chips × link_bw`` denominator in the roofline term.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# result shapes like  f32[16,128]{1,0}  or tuples ( f32[2]{0}, bf16[4,4]{...} )
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS) +
    r")(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def to_dict(self) -> Dict:
        return {"bytes_by_op": dict(self.bytes_by_op),
                "count_by_op": dict(self.count_by_op),
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by_op: Dict[str, int] = defaultdict(int)
    count_by_op: Dict[str, int] = defaultdict(int)
    seen_done = set()
    for m in _OP_LINE_RE.finditer(hlo_text):
        result_type, op = m.group(1), m.group(2)
        # async pairs: count -start only (the -done repeats the shape)
        line = m.group(0)
        if f"{op}-done" in line:
            continue
        bytes_by_op[op] += _shape_bytes(result_type)
        count_by_op[op] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
