"""Serving driver: QLM-managed cluster over real JAX engines.

Runs reduced models on CPU with the full QLM stack — request groups,
virtual queues, RWT estimator, global scheduler, LSO agents — against a
Poisson workload, and prints SLO attainment / throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 40 --rate 2.0

Cluster-mode flags (docs/cluster.md):

  --threaded          thread-per-engine serve loop (ThreadedCluster):
                      engines run real concurrent wall-clock rounds
                      instead of the single-thread round-robin poll
  --hetero            heterogeneous capacity tiers — instance i gets the
                      fast/mid/slow EngineConfig tier (slots x2/x1/x0.5,
                      decode_burst 4/2/1), each tier calibrated on its
                      own throwaway engine so the scheduler sees REAL
                      per-tier drain/swap costs; params are placed
                      through distributed/sharding.py rules
  --routing P         solver | slice — group-level MILP placement vs
                      slice-level load balancing (core/routing.py)
  --compare-drivers   run threaded AND round-robin on the same seed,
                      report both (tokens/s head-to-head)
  --compare-routing   run slice AND solver routing on the same seed,
                      report both (attainment head-to-head)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.virtual_queue import VirtualQueue
from repro.distributed.sharding import ShardingRules, build_shardings
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig, ThreadedCluster
from repro.sim.profiles import calibrate_from_engine


def build_registry(arch_names, key):
    """name -> (Model, params) for each requested arch (reduced configs)."""
    registry = {}
    for name in arch_names:
        cfg = get_arch(name).reduced()
        model = build_model(cfg)
        registry[name] = (model, model.init(key))
    return registry


def shard_registry(registry):
    """Place every model's params through the TP sharding rules.

    On this CPU driver the mesh is one device, so every leaf lands
    replicated — but the placement goes through the same
    ``build_shardings`` path a multi-device mesh would use, so the
    DEFAULT_RULES TP split (ff / heads over the "model" axis) applies
    unchanged when real devices are present.
    """
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:1])
    mesh = Mesh(devs, ("model",))
    rules = ShardingRules.default()
    out = {}
    for name, (model, params) in registry.items():
        sh = build_shardings(mesh, params, model.param_axes(), rules)
        out[name] = (model, jax.device_put(params, sh))
    return out


# fast / mid / slow capacity tiers for --hetero (instance i -> tier i%3):
# more slots = bigger batches = higher throughput; wider decode_burst =
# fewer host round-trips per token.  The tiers are calibrated separately,
# so the RWT estimator sees genuinely different drain/swap costs.
HETERO_TIERS = ({"slots_scale": 2.0, "decode_burst": 4},
                {"slots_scale": 1.0, "decode_burst": 2},
                {"slots_scale": 0.5, "decode_burst": 1})


def hetero_engine_cfg(base: EngineConfig, idx: int) -> EngineConfig:
    tier = HETERO_TIERS[idx % len(HETERO_TIERS)]
    return dataclasses.replace(
        base,
        max_slots=max(2, int(round(base.max_slots * tier["slots_scale"]))),
        decode_burst=tier["decode_burst"])


def calibrate_registry(registry, ecfg: EngineConfig) -> dict:
    """name -> HardwareProfile, each calibrated on ITS OWN model.

    One throwaway engine per model: the scheduler's swap/drain estimates
    are per (model, device) — reusing the arch-1 profile for every model
    (the old behavior) gave the solver wrong costs for every other arch.
    """
    hw_by_model = {}
    for name, (model, params) in registry.items():
        eng = ContinuousBatchingEngine(model, params, ecfg, model_name=name)
        hw_by_model[name] = calibrate_from_engine(
            eng, token_capacity=ecfg.resolved_kv_blocks() * ecfg.block_size)
    return hw_by_model


def build_cluster(args, registry, arch_names):
    """Engines + agents + controller honoring --hetero and --routing.

    Homogeneous: one calibration shared by every instance.  Hetero: one
    calibration per TIER (distinct EngineConfig), so each InstanceInfo
    carries its own per-model profiles and the scheduler's placement is
    heterogeneity-aware.
    """
    debug_inv = bool(getattr(args, "debug_invariants", False))
    base = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        decode_burst=args.decode_burst,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing,
                        debug_invariants=debug_inv)
    ecfgs = [hetero_engine_cfg(base, i) if args.hetero else base
             for i in range(args.instances)]
    hw_cache = {}
    engines, agents, infos = [], [], []
    for i, ecfg in enumerate(ecfgs):
        key = (ecfg.max_slots, ecfg.decode_burst)
        if key not in hw_cache:
            hw_cache[key] = calibrate_registry(registry, ecfg)
        m0, p0 = registry[arch_names[0]]
        eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=arch_names[0])
        vq = VirtualQueue(i)
        agents.append(QLMAgent(eng, vq, registry))
        engines.append(eng)
        infos.append(InstanceInfo(i, dict(hw_cache[key]), eng.model_name, vq))
    controller = QLMController(infos, QLMConfig(
        avg_batch_size=args.slots,
        routing=getattr(args, "routing", "solver"),
        debug_invariants=debug_inv))
    controller.attach_engines(engines)
    return engines, agents, infos, controller


def build_workload(args, arch_names, t_start: float):
    rng = np.random.default_rng(args.seed)
    classes = ["interactive", "batch1", "batch2"]
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, 100, size=int(rng.integers(4, 24))).tolist()
        r = make_request(prompt, rng.choice(arch_names), rng.choice(classes),
                         arrival_time=t_start + arrivals[i],
                         max_new_tokens=args.max_new_tokens)
        reqs.append(r)
    return reqs


def summarize(reqs, controller, engines, t_start: float, now: float) -> dict:
    """Printed-stats accounting, mirroring QLMController.slo_attainment:
    requests that never got a first token (rejected / shed / expired, or
    still queued past their deadline at ``now``) are SLO misses, not
    silently excluded."""
    import numpy as np
    # failed-quarantined requests are unconditional misses even when a
    # pre-crash first token landed in time (QLMController.slo_attainment
    # scores them the same way)
    failed = [r for r in reqs if r.failed]
    served = [r for r in reqs if r.ttft() is not None and not r.failed]
    dropped = [r for r in reqs if r.ttft() is None and not r.failed
               and (r.dropped() or now > r.deadline)]
    # rejections the caller's request list doesn't already cover (the
    # async path records rejections on requests that ARE in reqs)
    known = {id(r) for r in reqs}
    extra_rej = [r for r in controller.rejected if id(r) not in known]
    scored = len(served) + len(dropped) + len(extra_rej) + len(failed)
    met = sum(1 for r in served if r.slo_met())
    done_times = [r.completion_time for r in reqs if r.completion_time]
    span = max(max(done_times, default=now) - t_start, 1e-9)
    tokens = sum(e.stats.tokens_generated for e in engines)
    return {
        "requests": len(reqs),
        "served": len(served),
        "rejected": len(extra_rej) + sum(1 for r in reqs if r.rejected),
        "dropped_unserved": len(dropped),
        "failed": len(failed),
        # getattr: summarize also accepts stub controllers without the
        # supervision layer (qlint regression tests, older drivers)
        "redeliveries": getattr(controller, "redeliveries", 0),
        "hangs": getattr(controller, "hangs", 0),
        "drains": getattr(controller, "drains", 0),
        "replacements": getattr(controller, "replacements", 0),
        "migrations": getattr(controller, "migrations", 0),
        "dead_instances": sum(1 for i in range(len(controller.instances))
                              if not controller.is_alive(i))
        if hasattr(controller, "is_alive") else 0,
        # vacuous attainment is 1.0 (QLMController.slo_attainment): a
        # zero-request or all-unscored run met every SLO it was given,
        # and 0.0 would trip "attainment below threshold" alerting
        "slo_attainment": met / scored if scored else 1.0,
        # None, not float("nan"): NaN serializes as bare `NaN`, which is
        # not valid JSON and breaks downstream parsers of --json output
        "mean_ttft_s": float(np.mean([r.ttft() for r in served]))
        if served else None,
        "throughput_rps": len(served) / span,
        "evictions": sum(e.stats.evictions for e in engines),
        "swaps": sum(e.stats.model_swaps for e in engines),
        "tokens": tokens,
        "tokens_per_s": tokens / span,
        "prefix_hits": sum(e.stats.prefix_hits for e in engines),
        "prefix_shared_tokens": sum(e.stats.prefix_shared_tokens
                                    for e in engines),
    }


def _terminal(r) -> bool:
    return r.finished() or r.dropped()


def run_round_robin(args, registry, arch_names) -> dict:
    """Single-thread polling loop: one virtual "round" interleaves every
    engine in turn (the baseline --threaded is compared against)."""
    engines, agents, infos, controller = build_cluster(args, registry,
                                                       arch_names)
    t_start = time.monotonic()
    reqs = build_workload(args, arch_names, t_start)
    pending = list(reqs)
    deadline = t_start + args.max_wall
    while not all(_terminal(r) for r in reqs):
        now = time.monotonic()
        if now > deadline:
            break
        while pending and pending[0].arrival_time <= now:
            controller.submit(pending.pop(0), now)
        for inst, eng, agent in zip(infos, engines, agents):
            inst.current_model = eng.model_name
            agent.run_iteration()
        controller.tick(time.monotonic())
        if not any(e.num_active() for e in engines) and pending:
            time.sleep(min(0.01, max(0.0,
                                     pending[0].arrival_time - now)))
    stats = summarize(reqs, controller, engines, t_start, time.monotonic())
    stats["driver"] = "round-robin"
    stats["routing"] = controller.cfg.routing
    return stats


def run_threaded(args, registry, arch_names) -> dict:
    """Thread-per-engine loop: the main thread plays open-loop client
    (submitting on the wall-clock arrival schedule) while every engine
    decodes concurrently and the controller ticks on its own thread."""
    engines, agents, infos, controller = build_cluster(args, registry,
                                                       arch_names)
    cluster = ThreadedCluster(controller, agents, engines)
    t_start = time.monotonic()
    reqs = build_workload(args, arch_names, t_start)
    cluster.start()
    try:
        for r in reqs:
            time.sleep(max(0.0, r.arrival_time - time.monotonic()))
            controller.submit(r, time.monotonic())
        cluster.wait(lambda: all(_terminal(r) for r in reqs),
                     timeout=args.max_wall)
    finally:
        cluster.stop()
    stats = summarize(reqs, controller, engines, t_start, time.monotonic())
    stats["driver"] = "threaded"
    stats["routing"] = controller.cfg.routing
    stats["engine_rounds"] = list(cluster.rounds)
    stats["controller_ticks"] = cluster.ticks
    return stats


def run_once(args, registry, arch_names) -> dict:
    run = run_threaded if args.threaded else run_round_robin
    return run(args, registry, arch_names)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--arch2", default=None, help="second model for multi-model serving")
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--decode-burst", type=int, default=1,
                    help="fused decode iterations per engine dispatch "
                         "(QLMAgent.run_iteration drives steps(); 1 = the "
                         "single-step loop)")
    ap.add_argument("--backend", default=None,
                    choices=[None, "xla", "pallas", "paged-xla",
                             "paged-pallas"],
                    help="serving attention backend (None follows the "
                         "model config; prefix sharing needs a paged-* "
                         "backend's physical page pool)")
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="refcounted shared-prefix KV pages on the paged "
                         "backends (--no-prefix-sharing for the A/B "
                         "baseline; inert on dense backends)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threaded", action="store_true",
                    help="thread-per-engine serve loop (ThreadedCluster)")
    ap.add_argument("--hetero", action="store_true",
                    help="heterogeneous capacity tiers (fast/mid/slow), "
                         "each calibrated separately; params placed via "
                         "distributed/sharding.py")
    ap.add_argument("--routing", default="solver",
                    choices=["solver", "slice"],
                    help="group placement policy (core/routing.py)")
    ap.add_argument("--debug-invariants", action="store_true",
                    help="run the engine/queue invariant checkers every "
                         "round/tick")
    ap.add_argument("--max-wall", type=float, default=180.0,
                    help="wall-clock bound per run")
    ap.add_argument("--compare-drivers", action="store_true",
                    help="run threaded AND round-robin same-seed")
    ap.add_argument("--compare-routing", action="store_true",
                    help="run slice AND solver routing same-seed")
    ap.add_argument("--json", default=None, help="write final stats JSON")
    args = ap.parse_args(argv)

    key = jax.random.key(args.seed)

    # model registry (reduced configs — same code path as production)
    arch_names = [args.arch] + ([args.arch2] if args.arch2 else [])
    registry = build_registry(arch_names, key)
    if args.hetero:
        registry = shard_registry(registry)

    out = {}
    if args.compare_drivers:
        for threaded in (True, False):
            a = argparse.Namespace(**vars(args))
            a.threaded = threaded
            out["threaded" if threaded else "round-robin"] = \
                run_once(a, registry, arch_names)
    elif args.compare_routing:
        for routing in ("slice", "solver"):
            a = argparse.Namespace(**vars(args))
            a.routing = routing
            out[routing] = run_once(a, registry, arch_names)
    else:
        out["run"] = run_once(args, registry, arch_names)

    for name, st in out.items():
        if len(out) > 1:
            print(f"--- {name} ---")
        for k, v in st.items():
            print(f"{k:18s} {v:.3f}" if isinstance(v, float)
                  else f"{k:18s} {v}")
    if args.compare_drivers:
        t, rr = out["threaded"]["tokens_per_s"], \
            out["round-robin"]["tokens_per_s"]
        print(f"tokens/s           threaded {t:.1f} vs round-robin {rr:.1f} "
              f"({t / max(rr, 1e-9):.2f}x)")
    if args.compare_routing:
        print(f"attainment         slice "
              f"{out['slice']['slo_attainment']:.3f} vs solver "
              f"{out['solver']['slo_attainment']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    return out["run"] if "run" in out else out


if __name__ == "__main__":
    main()
