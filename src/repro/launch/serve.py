"""Serving driver: QLM-managed cluster over real JAX engines.

Runs reduced models on CPU with the full QLM stack — request groups,
virtual queues, RWT estimator, global scheduler, LSO agents — against a
Poisson workload, and prints SLO attainment / throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 40 --rate 2.0
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.sim.profiles import calibrate_from_engine


def build_registry(arch_names, key):
    """name -> (Model, params) for each requested arch (reduced configs)."""
    registry = {}
    for name in arch_names:
        cfg = get_arch(name).reduced()
        model = build_model(cfg)
        registry[name] = (model, model.init(key))
    return registry


def calibrate_registry(registry, ecfg: EngineConfig) -> dict:
    """name -> HardwareProfile, each calibrated on ITS OWN model.

    One throwaway engine per model: the scheduler's swap/drain estimates
    are per (model, device) — reusing the arch-1 profile for every model
    (the old behavior) gave the solver wrong costs for every other arch.
    """
    hw_by_model = {}
    for name, (model, params) in registry.items():
        eng = ContinuousBatchingEngine(model, params, ecfg, model_name=name)
        hw_by_model[name] = calibrate_from_engine(
            eng, token_capacity=ecfg.resolved_kv_blocks() * ecfg.block_size)
    return hw_by_model


def summarize(reqs, controller, engines, t_start: float, now: float) -> dict:
    """Printed-stats accounting, mirroring QLMController.slo_attainment:
    requests that never got a first token (rejected / shed / expired, or
    still queued past their deadline at ``now``) are SLO misses, not
    silently excluded."""
    import numpy as np
    # failed-quarantined requests are unconditional misses even when a
    # pre-crash first token landed in time (QLMController.slo_attainment
    # scores them the same way)
    failed = [r for r in reqs if r.failed]
    served = [r for r in reqs if r.ttft() is not None and not r.failed]
    dropped = [r for r in reqs if r.ttft() is None and not r.failed
               and (r.dropped() or now > r.deadline)]
    # rejections the caller's request list doesn't already cover (the
    # async path records rejections on requests that ARE in reqs)
    known = {id(r) for r in reqs}
    extra_rej = [r for r in controller.rejected if id(r) not in known]
    scored = len(served) + len(dropped) + len(extra_rej) + len(failed)
    met = sum(1 for r in served if r.slo_met())
    done_times = [r.completion_time for r in reqs if r.completion_time]
    span = max(max(done_times, default=now) - t_start, 1e-9)
    return {
        "requests": len(reqs),
        "served": len(served),
        "rejected": len(extra_rej) + sum(1 for r in reqs if r.rejected),
        "dropped_unserved": len(dropped),
        "failed": len(failed),
        # getattr: summarize also accepts stub controllers without the
        # supervision layer (qlint regression tests, older drivers)
        "redeliveries": getattr(controller, "redeliveries", 0),
        "hangs": getattr(controller, "hangs", 0),
        "drains": getattr(controller, "drains", 0),
        "replacements": getattr(controller, "replacements", 0),
        "migrations": getattr(controller, "migrations", 0),
        "dead_instances": sum(1 for i in range(len(controller.instances))
                              if not controller.is_alive(i))
        if hasattr(controller, "is_alive") else 0,
        # vacuous attainment is 1.0 (QLMController.slo_attainment): a
        # zero-request or all-unscored run met every SLO it was given,
        # and 0.0 would trip "attainment below threshold" alerting
        "slo_attainment": met / scored if scored else 1.0,
        # None, not float("nan"): NaN serializes as bare `NaN`, which is
        # not valid JSON and breaks downstream parsers of --json output
        "mean_ttft_s": float(np.mean([r.ttft() for r in served]))
        if served else None,
        "throughput_rps": len(served) / span,
        "evictions": sum(e.stats.evictions for e in engines),
        "swaps": sum(e.stats.model_swaps for e in engines),
        "tokens": sum(e.stats.tokens_generated for e in engines),
        "prefix_hits": sum(e.stats.prefix_hits for e in engines),
        "prefix_shared_tokens": sum(e.stats.prefix_shared_tokens
                                    for e in engines),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--arch2", default=None, help="second model for multi-model serving")
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--decode-burst", type=int, default=1,
                    help="fused decode iterations per engine dispatch "
                         "(QLMAgent.run_iteration drives steps(); 1 = the "
                         "single-step loop)")
    ap.add_argument("--backend", default=None,
                    choices=[None, "xla", "pallas", "paged-xla",
                             "paged-pallas"],
                    help="serving attention backend (None follows the "
                         "model config; prefix sharing needs a paged-* "
                         "backend's physical page pool)")
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="refcounted shared-prefix KV pages on the paged "
                         "backends (--no-prefix-sharing for the A/B "
                         "baseline; inert on dense backends)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    key = jax.random.key(args.seed)

    # model registry (reduced configs — same code path as production)
    arch_names = [args.arch] + ([args.arch2] if args.arch2 else [])
    registry = build_registry(arch_names, key)

    engines, agents, infos = [], [], []
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        decode_burst=args.decode_burst,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing)
    # per-model hardware profiles (each arch calibrated on its own engine):
    # the scheduler's swap/drain costs for --arch2 come from arch2's real
    # timings, not a copy of arch-1's
    hw_by_model = calibrate_registry(registry, ecfg)
    for i in range(args.instances):
        m0, p0 = registry[arch_names[0]]
        eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=arch_names[0])
        vq = VirtualQueue(i)
        agent = QLMAgent(eng, vq, registry)
        engines.append(eng)
        agents.append(agent)
        infos.append(InstanceInfo(i, dict(hw_by_model), eng.model_name, vq))
    controller = QLMController(infos, QLMConfig(avg_batch_size=args.slots))

    # workload
    classes = ["interactive", "batch1", "batch2"]
    t_start = time.monotonic()
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, 100, size=int(rng.integers(4, 24))).tolist()
        r = make_request(prompt, rng.choice(arch_names), rng.choice(classes),
                         arrival_time=t_start + arrivals[i],
                         max_new_tokens=args.max_new_tokens)
        reqs.append(r)

    pending = list(reqs)
    done = 0
    while done < len(reqs):
        now = time.monotonic()
        while pending and pending[0].arrival_time <= now:
            r = pending.pop(0)
            for inst, eng in zip(infos, engines):
                inst.current_model = eng.model_name
            controller.submit(r, now)
        for inst, eng, agent in zip(infos, engines, agents):
            inst.current_model = eng.model_name
            agent.run_iteration()
        done = sum(1 for r in reqs if r.finished())
        if not any(e.num_active() for e in engines) and pending:
            time.sleep(min(0.01, max(0.0, pending[0].arrival_time - time.monotonic())))

    stats = summarize(reqs, controller, engines, t_start, time.monotonic())
    for k, v in stats.items():
        print(f"{k:18s} {v:.3f}" if isinstance(v, float) else f"{k:18s} {v}")
    return stats


if __name__ == "__main__":
    main()
