"""Serving driver: QLM-managed cluster over real JAX engines.

Runs reduced models on CPU with the full QLM stack — request groups,
virtual queues, RWT estimator, global scheduler, LSO agents — against a
Poisson workload, and prints SLO attainment / throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 40 --rate 2.0
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.sim.profiles import calibrate_from_engine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--arch2", default=None, help="second model for multi-model serving")
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--decode-burst", type=int, default=1,
                    help="fused decode iterations per engine dispatch "
                         "(QLMAgent.run_iteration drives steps(); 1 = the "
                         "single-step loop)")
    ap.add_argument("--backend", default=None,
                    choices=[None, "xla", "pallas", "paged-xla",
                             "paged-pallas"],
                    help="serving attention backend (None follows the "
                         "model config; prefix sharing needs a paged-* "
                         "backend's physical page pool)")
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="refcounted shared-prefix KV pages on the paged "
                         "backends (--no-prefix-sharing for the A/B "
                         "baseline; inert on dense backends)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    key = jax.random.key(args.seed)

    # model registry (reduced configs — same code path as production)
    arch_names = [args.arch] + ([args.arch2] if args.arch2 else [])
    registry = {}
    for name in arch_names:
        cfg = get_arch(name).reduced()
        model = build_model(cfg)
        registry[name] = (model, model.init(key))

    engines, agents, infos = [], [], []
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        decode_burst=args.decode_burst,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing)
    for i in range(args.instances):
        m0, p0 = registry[arch_names[0]]
        eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=arch_names[0])
        hw = calibrate_from_engine(eng, token_capacity=ecfg.resolved_kv_blocks() * ecfg.block_size)
        vq = VirtualQueue(i)
        agent = QLMAgent(eng, vq, registry)
        engines.append(eng)
        agents.append(agent)
        infos.append(InstanceInfo(i, {n: hw for n in arch_names},
                                  eng.model_name, vq))
    controller = QLMController(infos, QLMConfig(avg_batch_size=args.slots))

    # workload
    classes = ["interactive", "batch1", "batch2"]
    t_start = time.monotonic()
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, 100, size=int(rng.integers(4, 24))).tolist()
        r = make_request(prompt, rng.choice(arch_names), rng.choice(classes),
                         arrival_time=t_start + arrivals[i],
                         max_new_tokens=args.max_new_tokens)
        reqs.append(r)

    pending = list(reqs)
    done = 0
    while done < len(reqs):
        now = time.monotonic()
        while pending and pending[0].arrival_time <= now:
            r = pending.pop(0)
            for inst, eng in zip(infos, engines):
                inst.current_model = eng.model_name
            controller.submit(r, now)
        for inst, eng, agent in zip(infos, engines, agents):
            inst.current_model = eng.model_name
            agent.run_iteration()
        done = sum(1 for r in reqs if r.finished())
        if not any(e.num_active() for e in engines) and pending:
            time.sleep(min(0.01, max(0.0, pending[0].arrival_time - time.monotonic())))

    ttfts = [r.ttft() for r in reqs]
    met = sum(1 for r in reqs if r.slo_met())
    span = max(r.completion_time for r in reqs) - t_start
    stats = {
        "requests": len(reqs),
        "slo_attainment": met / len(reqs),
        "mean_ttft_s": float(np.mean(ttfts)),
        "throughput_rps": len(reqs) / span,
        "evictions": sum(e.stats.evictions for e in engines),
        "swaps": sum(e.stats.model_swaps for e in engines),
        "tokens": sum(e.stats.tokens_generated for e in engines),
        "prefix_hits": sum(e.stats.prefix_hits for e in engines),
        "prefix_shared_tokens": sum(e.stats.prefix_shared_tokens
                                    for e in engines),
    }
    for k, v in stats.items():
        print(f"{k:18s} {v:.3f}" if isinstance(v, float) else f"{k:18s} {v}")
    return stats


if __name__ == "__main__":
    main()
