"""Async serving driver: QLM cluster behind the backpressure front end.

Same reduced-model JAX cluster as ``launch/serve.py``, but driven through
``serving.frontend.AsyncServer``: a bounded request queue with high/low
backpressure watermarks and 429-style rejection, per-request deadlines
(expired requests never dispatch), client cancellation that frees KV
mid-decode, token streaming, and graceful shedding of batch traffic when
interactive SLOs are predicted to be violated.

  PYTHONPATH=src python -m repro.launch.async_serve --arch granite-3-2b \
      --requests 40 --rate 4.0 --queue-depth 32 --shed-policy defer

Flags beyond serve.py's:

  --queue-depth N     hard bound on queued-unstarted requests (429 past it);
                      watermarks default to 3/4 (engage) and 1/2 (release)
  --shed-policy P     defer | drop | off — what happens to running
                      batch-class slots when an interactive violation is
                      predicted (defer = evict resumable, drop = cancel)
  --admit-drain B     off | slo | SECONDS — RWT admission gate bound
  --sessions N        drive N multi-turn sessions (--session-turns each)
                      through the queue instead of independent requests;
                      follow-up turns carry the conversation as a prompt
                      prefix (prefix-cache traffic)
  --slo-scale S       multiply every request's TTFT SLO by S (reduced
                      models on CPU need sub-second SLOs to see pressure)
  --compare-sync      also run the synchronous serve.py-style loop on an
                      identical same-seed workload and report both
  --json PATH         write the stats dict as JSON (CI smoke asserts on it)
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import numpy as np

from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import SLO_CLASSES, make_request
from repro.core.virtual_queue import VirtualQueue
from repro.data.workload import SessionSpec, generate_sessions
from repro.launch.serve import build_registry, calibrate_registry, summarize
from repro.serving import (AsyncServer, ContinuousBatchingEngine,
                           EngineConfig, FrontendConfig, run_session)

CLASSES = ("interactive", "batch1", "batch2")


def build_requests(args, arch_names):
    """Same-seed reproducible open-loop workload: (request, arrival_offset)
    pairs.  Rebuilt per run — Request objects are mutated by serving."""
    rng = np.random.default_rng(args.seed)
    offs = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    out = []
    for i in range(args.requests):
        prompt = rng.integers(0, 100,
                              size=int(rng.integers(4, 24))).tolist()
        r = make_request(prompt, rng.choice(arch_names), rng.choice(CLASSES),
                         max_new_tokens=args.max_new_tokens)
        if r.slo_class != "interactive":
            r.max_new_tokens = args.batch_new_tokens
        r.slo *= args.slo_scale
        out.append((r, float(offs[i])))
    return out


def build_cluster(args, registry, hw_by_model, arch_names):
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        decode_burst=args.decode_burst,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing)
    engines, agents, infos = [], [], []
    for i in range(args.instances):
        m0, p0 = registry[arch_names[0]]
        eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=arch_names[0])
        vq = VirtualQueue(i)
        agents.append(QLMAgent(eng, vq, registry))
        engines.append(eng)
        infos.append(InstanceInfo(i, dict(hw_by_model), eng.model_name, vq))
    controller = QLMController(
        infos, QLMConfig(avg_batch_size=args.slots,
                         reschedule_cooldown=args.reschedule_cooldown,
                         routing=getattr(args, "routing", "solver")))
    controller.attach_engines(engines)
    return engines, agents, infos, controller


def class_attainment(reqs, cls: str, now: float) -> float:
    """Per-class SLO attainment with the same scoring rules as
    QLMController.slo_attainment (drops and stranded-past-deadline
    requests are misses)."""
    scored = hits = 0
    for r in reqs:
        if r.slo_class != cls:
            continue
        met = r.slo_met()
        if met is not None:
            scored += 1
            hits += int(met)
        elif r.dropped() or now > r.deadline:
            scored += 1
    return hits / scored if scored else 1.0


def run_sync(args, registry, hw_by_model, arch_names) -> dict:
    """The serve.py-style synchronous polling loop (the baseline the
    async front end must beat on interactive attainment under overload)."""
    engines, agents, infos, controller = build_cluster(
        args, registry, hw_by_model, arch_names)
    pairs = build_requests(args, arch_names)
    t_start = time.monotonic()
    for r, off in pairs:
        r.arrival_time = t_start + off
    reqs = [r for r, _ in pairs]
    pending = list(reqs)
    deadline = t_start + args.max_wall
    while any(not r.finished() for r in reqs):
        now = time.monotonic()
        if now > deadline:
            break
        while pending and pending[0].arrival_time <= now:
            controller.submit(pending.pop(0), now)
        for inst, eng, agent in zip(infos, engines, agents):
            inst.current_model = eng.model_name
            agent.run_iteration()
        if not any(e.num_active() for e in engines) and pending:
            time.sleep(min(0.01, max(0.0,
                                     pending[0].arrival_time - now)))
    now = time.monotonic()
    stats = summarize(reqs, controller, engines, t_start, now)
    stats["slo_attainment"] = controller.slo_attainment(now)
    for cls in CLASSES:
        stats[f"attainment_{cls}"] = class_attainment(reqs, cls, now)
    return stats


async def run_async(args, registry, hw_by_model, arch_names) -> dict:
    engines, agents, infos, controller = build_cluster(
        args, registry, hw_by_model, arch_names)
    admission = None if args.admit_drain in (None, "off") \
        else ("slo" if args.admit_drain == "slo" else float(args.admit_drain))
    fcfg = FrontendConfig(
        queue_depth=args.queue_depth, shed_policy=args.shed_policy,
        admission=admission,
        interactive_slo_ceiling=SLO_CLASSES["interactive"] * args.slo_scale,
        shed_cooldown_s=args.shed_cooldown)
    server = AsyncServer(controller, agents, fcfg)
    free0 = [e.block_mgr.free_blocks for e in engines]
    t_start = time.monotonic()
    reqs, sessions = [], []

    async def feed(req, offset):
        req.arrival_time = t_start + offset
        await asyncio.sleep(max(0.0, req.arrival_time - time.monotonic()))
        await server.submit(req)

    async def feed_session(sess):
        await asyncio.sleep(max(0.0, sess.arrival_time - time.monotonic()))
        await run_session(server, sess)

    tasks = []
    async with server:
        if args.sessions > 0:
            spec = SessionSpec(n_sessions=args.sessions,
                               turns=args.session_turns, seed=args.seed,
                               model=arch_names[0], slo_class="interactive",
                               arrival_rate=args.rate,
                               think_time_s=args.think_time,
                               max_new_tokens=args.max_new_tokens,
                               vocab=100)
            sessions = generate_sessions(spec)
            for s in sessions:
                s.arrival_time = t_start + s.arrival_time
                s.slo_s = SLO_CLASSES[s.slo_class] * args.slo_scale
                tasks.append(asyncio.ensure_future(feed_session(s)))
        else:
            pairs = build_requests(args, arch_names)
            reqs = [r for r, _ in pairs]
            tasks = [asyncio.ensure_future(feed(r, off)) for r, off in pairs]
        try:
            await asyncio.wait_for(asyncio.gather(*tasks), args.max_wall)
            await asyncio.wait_for(server.drain(), args.max_wall)
        except asyncio.TimeoutError:
            for t in tasks:
                t.cancel()
            await server.stop(cancel_outstanding=True)
    now = time.monotonic()
    if args.sessions > 0:
        reqs = reqs + [r for s in sessions for r in s.requests]
    stats = summarize(reqs, controller, engines, t_start, now)
    stats["slo_attainment"] = controller.slo_attainment(now)
    for cls in CLASSES:
        stats[f"attainment_{cls}"] = class_attainment(reqs, cls, now)
    fs = server.stats
    stats.update({
        "accepted": fs.accepted,
        "rejected": fs.rejected,
        "rejected_backpressure": fs.rejected_backpressure,
        "expired": fs.expired,
        "cancelled": fs.cancelled,
        "shed_deferred": fs.shed_deferred,
        "shed_dropped": fs.shed_dropped,
        "deferred_groups": fs.deferred_groups,
        "tokens_streamed": fs.tokens_streamed,
        "acceptance_rate": fs.acceptance_rate,
        "rejection_rate": fs.rejection_rate,
        "expiry_rate": fs.expiry_rate,
        "mean_tokens_per_accepted": fs.mean_tokens_per_accepted,
        "max_queue_depth": fs.max_queue_depth,
        "backpressure_engagements": fs.backpressure_engagements,
        "rejected_unservable": fs.rejected_unservable,
        "rejected_capacity": fs.rejected_capacity,
        "engine_failures": fs.engine_failures,
        "redeliveries": controller.redeliveries,
        "failed_quarantined": len(controller.failed),
        "dead_instances": sum(1 for i in range(len(controller.instances))
                              if not controller.is_alive(i)),
        "kv_blocks_leaked": sum(
            f0 - e.block_mgr.free_blocks
            for f0, e in zip(free0, engines)),
        "clean_shutdown": int(not server._live),
    })
    if args.sessions > 0:
        stats["sessions"] = len(sessions)
        stats["session_turns_served"] = sum(
            1 for s in sessions for r in s.requests if r.ttft() is not None)
    return stats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--arch2", default=None)
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--batch-new-tokens", type=int, default=None,
                    help="max_new_tokens for batch-class requests "
                         "(default: same as --max-new-tokens)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--decode-burst", type=int, default=1)
    ap.add_argument("--backend", default=None,
                    choices=[None, "xla", "pallas", "paged-xla",
                             "paged-pallas"])
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue-depth", type=int, default=32)
    ap.add_argument("--shed-policy", default="defer",
                    choices=["defer", "drop", "off"])
    ap.add_argument("--shed-cooldown", type=float, default=0.25)
    ap.add_argument("--admit-drain", default="off",
                    help="off | slo | SECONDS (RWT admission gate)")
    ap.add_argument("--sessions", type=int, default=0)
    ap.add_argument("--session-turns", type=int, default=3)
    ap.add_argument("--think-time", type=float, default=0.05)
    ap.add_argument("--slo-scale", type=float, default=1.0)
    ap.add_argument("--reschedule-cooldown", type=float, default=0.5)
    ap.add_argument("--routing", default="solver",
                    choices=["solver", "slice"],
                    help="group placement policy (core/routing.py)")
    ap.add_argument("--max-wall", type=float, default=120.0,
                    help="wall-clock bound; past it outstanding requests "
                         "are cancelled and the server shuts down cleanly")
    ap.add_argument("--compare-sync", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.batch_new_tokens is None:
        args.batch_new_tokens = args.max_new_tokens

    key = jax.random.key(args.seed)
    arch_names = [args.arch] + ([args.arch2] if args.arch2 else [])
    registry = build_registry(arch_names, key)
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        decode_burst=args.decode_burst,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing)
    hw_by_model = calibrate_registry(registry, ecfg)

    stats = asyncio.run(run_async(args, registry, hw_by_model, arch_names))
    out = {"async": stats}
    if args.compare_sync:
        out["sync"] = run_sync(args, registry, hw_by_model, arch_names)
    for name, st in out.items():
        print(f"--- {name} ---")
        for k, v in st.items():
            print(f"{k:24s} {v:.3f}" if isinstance(v, float)
                  else f"{k:24s} {v}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    main()
