"""True-concurrency cluster serve loop: one thread per QLM agent/engine.

The round-robin drivers (``launch/chaos.py``, ``launch/serve.py``) share
one virtual clock and interleave engine rounds on a single thread, so no
cross-engine overlap is ever real.  ``ThreadedCluster`` runs each
``QLMAgent`` on its own thread against REAL wall-clock rounds — three
heterogeneous engines decode simultaneously, a model swap on one
instance overlaps its siblings' decodes — with the controller's tick
loop (watchdog, heartbeats, drain completion, migration sweep,
violation reschedule) on a dedicated supervisor thread.

Locking discipline (see also ``core/qlm.py`` and ``core/lso.py``):

  * ``QLMController.lock`` (RLock) serializes the whole queue layer —
    every controller entry point takes it, and each agent's
    ``queue_lock`` is bound to it here so VQ pulls / head sync
    serialize against ticks, submits, and recovery.
  * ``engine.lock`` (RLock, per engine) covers one engine's internals.
    The agent thread holds it for the full round quantum
    (``QLMAgent.run_iteration``); the controller side only ever
    try-locks / bounded-locks it (``qlm._engine_guard``), so the
    engine->controller acquisition order of agent threads cannot
    deadlock against the controller's controller->engine touches.
  * Agent-thread-only calls: ``engine.step/steps``, ``agent.sync``,
    ``agent._pull``.  Controller-thread calls reach engines only
    through the guarded LSO sites (migration materialize, drain
    eviction, dead-engine salvage).

Failure handling matches the round-robin driver: an ``EngineFailure``
raised by a round is reported to the controller (supervision decides
dead vs degraded), the agent resets, and the thread parks while its
instance is departed — ``replace(idx, engine, agent)`` installs fresh
capacity in the slot and the parked thread resumes on it.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

from repro.serving.faults import EngineFailure


class ThreadedCluster:
    """Thread-per-engine serve loop over a ``QLMController``.

    Drivers submit through ``controller.submit`` (thread-safe) while the
    cluster runs; ``wait`` blocks until a predicate holds or a wall
    timeout expires; ``stop`` joins every thread.  Engines keep their
    injected lifecycle clock (wall by default) — rounds themselves are
    real wall-clock either way.
    """

    def __init__(self, controller, agents: List, engines: List, *,
                 clock: Callable[[], float] = time.monotonic,
                 tick_interval: float = 0.02,
                 idle_sleep: float = 0.002):
        self.controller = controller
        self.agents = list(agents)
        self.engines = list(engines)
        self.clock = clock
        self.tick_interval = tick_interval
        self.idle_sleep = idle_sleep
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._tick_thread: Optional[threading.Thread] = None
        self.rounds = [0] * len(self.agents)
        self.failures = [0] * len(self.agents)
        self.ticks = 0
        # crash-isolation: an exception that is NOT an EngineFailure is a
        # bug in the stack, not an injected fault — it must surface to
        # the driver, not die silently with the thread
        self.errors: List[BaseException] = []
        # optional per-round callback ``hook(idx)`` invoked from agent
        # idx's OWN thread between rounds (engine lock free there).
        # Drivers use it for round-granular lifecycle triggers — e.g.
        # chaos drains an instance at the exact round its target holds
        # co-resident sharers, which a polling loop would miss.
        self.round_hook: Optional[Callable[[int], None]] = None
        for agent in self.agents:
            agent.queue_lock = controller.lock

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ThreadedCluster":
        if self._threads:
            raise RuntimeError("cluster already started")
        self._stop.clear()
        for idx in range(len(self.agents)):
            t = threading.Thread(target=self._agent_loop, args=(idx,),
                                 name=f"qlm-agent-{idx}", daemon=True)
            self._threads.append(t)
            t.start()
        self._tick_thread = threading.Thread(target=self._tick_loop,
                                             name="qlm-controller",
                                             daemon=True)
        self._tick_thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        self._stop.set()
        deadline = time.monotonic() + timeout
        for t in self._threads + ([self._tick_thread]
                                  if self._tick_thread else []):
            t.join(max(0.0, deadline - time.monotonic()))
        alive = [t.name for t in self._threads if t.is_alive()]
        self._threads = []
        self._tick_thread = None
        if alive:
            raise RuntimeError(f"cluster threads failed to join: {alive}")
        if self.errors:
            raise self.errors[0]

    def wait(self, predicate: Callable[[], bool],
             timeout: float = 60.0, poll: float = 0.01) -> bool:
        """Block until ``predicate()`` (called under the controller lock)
        holds, the cluster errors out, or ``timeout`` wall-seconds pass.
        Returns whether the predicate held."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.errors:
                return False
            with self.controller.lock:
                if predicate():
                    return True
            time.sleep(poll)
        return False

    def replace(self, idx: int, engine, agent, now: Optional[float] = None,
                hw_by_model=None, model_name=None) -> None:
        """Install fresh capacity in a departed slot: controller-side
        ``replace_instance`` plus swapping the runtime's agent/engine so
        the parked thread picks the new pair up on its next check."""
        now = self.clock() if now is None else now
        agent.queue_lock = self.controller.lock
        with self.controller.lock:
            self.controller.replace_instance(idx, engine, now,
                                             hw_by_model=hw_by_model,
                                             model_name=model_name)
            self.engines[idx] = engine
            self.agents[idx] = agent

    # -- thread bodies -----------------------------------------------------
    def _agent_loop(self, idx: int) -> None:
        ctl = self.controller
        while not self._stop.is_set():
            if not ctl.is_alive(idx):
                # departed slot: park cheaply until replaced or stopped
                self._stop.wait(self.idle_sleep * 10)
                continue
            agent = self.agents[idx]
            try:
                agent.run_iteration()
            except EngineFailure as e:
                self.failures[idx] += 1
                ctl.report_engine_failure(idx, e, self.clock(),
                                          engine=agent.engine)
                agent.reset()
                continue
            except BaseException as e:  # noqa: BLE001 — surfaced via stop()
                self.errors.append(e)
                return
            with ctl.lock:
                # swap/drain estimates read instances[].current_model; the
                # round-robin drivers refresh it every round, threaded
                # agents must too (a live swap lands mid-traffic here)
                ctl.instances[idx].current_model = agent.engine.model_name
                ctl.heartbeat(idx, self.clock())
            self.rounds[idx] += 1
            hook = self.round_hook
            if hook is not None:
                try:
                    hook(idx)
                except BaseException as e:  # noqa: BLE001 — surfaced via stop()
                    self.errors.append(e)
                    return
            if self._idle(idx, agent):
                time.sleep(self.idle_sleep)

    def _idle(self, idx: int, agent) -> bool:
        """No residents and nothing pullable: back off instead of
        spinning.  The VQ read takes the controller lock (group lists
        mutate under it); the engine check is agent-thread-local."""
        try:
            if agent.engine.num_active() > 0:
                return False
        except EngineFailure:
            return True
        with self.controller.lock:
            return agent.vq.pending_requests() == 0

    def _tick_loop(self) -> None:
        ctl = self.controller
        while not self._stop.is_set():
            try:
                ctl.tick(self.clock())
            except BaseException as e:  # noqa: BLE001 — surfaced via stop()
                self.errors.append(e)
                return
            self.ticks += 1
            self._stop.wait(self.tick_interval)

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        return {
            "rounds": list(self.rounds),
            "failures": list(self.failures),
            "ticks": self.ticks,
            "errors": [repr(e) for e in self.errors],
        }
