from repro.serving.cluster import ThreadedCluster
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig, EngineStats
from repro.serving.faults import (EngineCrashed, EngineDead, EngineFailure,
                                  FaultPlan, FaultSpec, FaultyEngine,
                                  TransientEngineError)
from repro.serving.frontend import (AsyncServer, FrontendConfig,
                                    FrontendStats, RequestStream, run_session)
from repro.serving.kv_cache import BlockManager, OutOfBlocksError

__all__ = ["ContinuousBatchingEngine", "EngineConfig", "EngineStats",
           "BlockManager", "OutOfBlocksError",
           "AsyncServer", "FrontendConfig", "FrontendStats", "RequestStream",
           "run_session", "ThreadedCluster",
           "EngineFailure", "EngineCrashed", "EngineDead",
           "TransientEngineError", "FaultSpec", "FaultPlan", "FaultyEngine"]
