"""Continuous-batching LLM engine (the "LLM serving instance" of Def. 2.3).

Real-execution engine: actual JAX models (reduced configs on CPU; the same
code path jit-compiles for TPU), iteration-level scheduling a la
Orca/vLLM:

  * fixed slot array (``max_slots``) holding the running batch,
  * paged KV accounting via ``BlockManager`` (admission + preemption),
  * ``step()`` = admit-from-pull-source, then ONE decode iteration for all
    active slots,
  * request eviction with host-side KV/state snapshots (the paper's
    eviction LSO — resume skips prefill entirely),
  * model swapping (flush KV, replace weights; paper's swap LSO).

All cache pytrees have layout (layers/sites, batch, ...), so slot insert /
extract are uniform ``tree_map``s over axis 1.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import Request
from repro.models.model_factory import Model
from repro.serving.kv_cache import BlockManager


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_seq_len: int = 512
    block_size: int = 16
    kv_blocks: Optional[int] = None    # None => max_slots*max_seq_len worth
    eos_token: Optional[int] = None
    dtype: Any = jnp.float32

    def resolved_kv_blocks(self) -> int:
        if self.kv_blocks is not None:
            return self.kv_blocks
        return (self.max_slots * self.max_seq_len) // self.block_size


@dataclasses.dataclass
class EngineStats:
    decode_iterations: int = 0
    prefills: int = 0
    evictions: int = 0
    resumes: int = 0
    model_swaps: int = 0
    tokens_generated: int = 0
    preemptions: int = 0
    decode_time: float = 0.0
    prefill_time: float = 0.0
    swap_time: float = 0.0


class ContinuousBatchingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 model_name: str = "default",
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.model = model
        self.params = params
        self.model_name = model_name
        self.stats = EngineStats()

        self.block_mgr = BlockManager(cfg.resolved_kv_blocks(), cfg.block_size)
        self.slots: List[Optional[Request]] = [None] * cfg.max_slots
        self.lengths = np.zeros(cfg.max_slots, np.int32)
        self.cache = model.init_cache(cfg.max_slots, cfg.max_seq_len, cfg.dtype)
        self.pull_source: Optional[Callable[[], Optional[Request]]] = None
        self.completed: List[Request] = []
        self._pushback: Optional[Request] = None

        self._decode_fn = jax.jit(self._decode_impl)
        self._prefill_cache = {}  # per-length jitted prefill

    # ------------------------------------------------------------------
    # jitted compute
    # ------------------------------------------------------------------
    def _decode_impl(self, params, cache, tokens, lengths):
        logits, new_cache = self.model.decode_step(params, cache, tokens, lengths)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    def _prefill_one(self, prompt: np.ndarray, extras: Dict[str, Any]):
        """Prefill a single request (batch=1, exact length — SSM-state safe)."""
        L = len(prompt)
        key = (L,) + tuple(sorted(extras))
        if key not in self._prefill_cache:
            def fn(params, batch, cache):
                logits, new_cache = self.model.prefill(params, batch, cache)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return tok, new_cache
            self._prefill_cache[key] = jax.jit(fn)
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
        batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
        cache1 = self.model.init_cache(1, self.cfg.max_seq_len, self.cfg.dtype)
        tok, cache1 = self._prefill_cache[key](self.params, batch, cache1)
        return int(tok[0]), cache1

    # ------------------------------------------------------------------
    # slot plumbing
    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _insert_cache(self, slot_cache, b: int) -> None:
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, b].set(one[:, 0]), self.cache, slot_cache)

    def _extract_cache(self, b: int):
        return jax.tree.map(lambda full: np.asarray(full[:, b]), self.cache)

    def _restore_cache(self, snapshot, b: int) -> None:
        self.cache = jax.tree.map(
            lambda full, snap: full.at[:, b].set(jnp.asarray(snap)),
            self.cache, snapshot)

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def num_active(self) -> int:
        return len(self.active_slots())

    def running_requests(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    # ------------------------------------------------------------------
    # admission (request pulling LSO actuation point)
    # ------------------------------------------------------------------
    def can_admit(self, req: Request) -> bool:
        if self._free_slot() is None:
            return False
        need = req.prompt_len + req.generated + 1
        if need > self.cfg.max_seq_len:
            return False
        return self.block_mgr.can_allocate(need)

    def admit(self, req: Request, extras: Optional[Dict[str, Any]] = None) -> bool:
        """Prefill (or snapshot-restore) ``req`` into a free slot."""
        slot = self._free_slot()
        if slot is None or not self.can_admit(req):
            return False
        t0 = time.monotonic()
        total = req.prompt_len + req.generated
        if req.snapshot is not None:
            # eviction resume: restore KV/state, no prefill recompute
            self._restore_cache(req.snapshot["cache"], slot)
            self.lengths[slot] = req.snapshot["length"]
            req.snapshot = None
            self.block_mgr.allocate(req.req_id, total + 1)
            self.stats.resumes += 1
        else:
            tok, cache1 = self._prefill_one(np.asarray(req.prompt_tokens),
                                            extras or req.extras or {})
            self._insert_cache(cache1, slot)
            self.lengths[slot] = req.prompt_len
            self.block_mgr.allocate(req.req_id, req.prompt_len + 1)
            if req.first_token_time is None:
                req.first_token_time = self.clock()
            req.output_tokens.append(tok)
            req.generated += 1
            self.stats.prefills += 1
        self.slots[slot] = req
        self.stats.prefill_time += time.monotonic() - t0
        return True

    # ------------------------------------------------------------------
    # eviction LSO
    # ------------------------------------------------------------------
    def evict_slot(self, slot: int) -> Request:
        """Snapshot the slot's KV/state to host memory and free it.

        TPU adaptation of the paper's async GPU→CPU copy: ``device_get`` of
        the slot slice (the engine overlaps this with the next decode
        iteration when dispatch is async).
        """
        req = self.slots[slot]
        assert req is not None
        req.snapshot = {
            "cache": self._extract_cache(slot),
            "length": int(self.lengths[slot]),
        }
        req.n_evictions += 1
        self.block_mgr.free(req.req_id)
        self.slots[slot] = None
        self.lengths[slot] = 0
        self.stats.evictions += 1
        return req

    def evict_request(self, req_id: int) -> Optional[Request]:
        for i, r in enumerate(self.slots):
            if r is not None and r.req_id == req_id:
                return self.evict_slot(i)
        return None

    def flush(self) -> List[Request]:
        """Evict everything (used before a model swap)."""
        return [self.evict_slot(i) for i in self.active_slots()]

    # ------------------------------------------------------------------
    # model swapping LSO
    # ------------------------------------------------------------------
    def swap_model(self, model: Model, params, model_name: str) -> List[Request]:
        t0 = time.monotonic()
        evicted = self.flush()
        # swapped-out requests' snapshots belong to the OLD model: drop them
        # (their KV is meaningless under the new weights)
        for r in evicted:
            r.snapshot = None
        self.model = model
        self.params = params
        self.model_name = model_name
        self.cache = model.init_cache(self.cfg.max_slots, self.cfg.max_seq_len,
                                      self.cfg.dtype)
        self.block_mgr.reset()
        self._decode_fn = jax.jit(self._decode_impl)
        self._prefill_cache.clear()
        self.stats.model_swaps += 1
        self.stats.swap_time += time.monotonic() - t0
        return evicted

    # ------------------------------------------------------------------
    # one iteration
    # ------------------------------------------------------------------
    def take_pushback(self) -> Optional[Request]:
        r, self._pushback = self._pushback, None
        return r

    def step(self) -> List[Request]:
        """Admit from the pull source, then one decode iteration.
        Returns requests completed this step."""
        # 1. request pulling: admit while capacity allows
        if self.pull_source is not None:
            while self._pushback is None:
                if self._free_slot() is None:
                    break
                req = self.pull_source()
                if req is None:
                    break
                if not self.admit(req):
                    # couldn't admit (KV capacity): hand back to the virtual
                    # queue owner via take_pushback().
                    self._pushback = req
                    break

        active = self.active_slots()
        if not active:
            return []

        # 2. continuous-batching decode iteration
        t0 = time.monotonic()
        tokens = np.zeros(self.cfg.max_slots, np.int32)
        for i in active:
            tokens[i] = self.slots[i].output_tokens[-1] if self.slots[i].output_tokens \
                else self.slots[i].prompt_tokens[-1]
        next_tokens, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(self.lengths))
        next_tokens = np.asarray(next_tokens)
        self.stats.decode_iterations += 1
        self.stats.decode_time += time.monotonic() - t0

        done: List[Request] = []
        now = self.clock()
        for i in active:
            req = self.slots[i]
            # block accounting; preempt on OOM (vLLM-style)
            if not self.block_mgr.append_token(req.req_id):
                self.stats.preemptions += 1
                self.evict_slot(i)
                continue
            self.lengths[i] += 1
            tok = int(next_tokens[i])
            req.output_tokens.append(tok)
            req.generated += 1
            self.stats.tokens_generated += 1
            if req.first_token_time is None:
                req.first_token_time = now
            eos = (self.cfg.eos_token is not None and tok == self.cfg.eos_token)
            if eos or req.generated >= req.max_new_tokens \
                    or self.lengths[i] >= self.cfg.max_seq_len - 1:
                req.completion_time = now
                done.append(req)
                self.block_mgr.free(req.req_id)
                self.slots[i] = None
                self.lengths[i] = 0
        self.completed.extend(done)
        return done

    # ------------------------------------------------------------------
    # profiling (feeds the RWT estimator + simulator)
    # ------------------------------------------------------------------
    def profile(self, prompts: List[np.ndarray], max_new_tokens: int = 32) -> Dict[str, float]:
        """Run one batch (paper §6 "Hardware Profiling": a single batch run)
        and return {prefill_time P, decode_per_token d, throughput theta}."""
        import repro.core.request as req_mod
        reqs = [req_mod.Request(prompt_tokens=p, model=self.model_name,
                                slo=1e9, max_new_tokens=max_new_tokens)
                for p in prompts]
        t0 = time.monotonic()
        for r in reqs:
            if not self.admit(r):
                break
        prefill_t = time.monotonic() - t0
        n_admitted = self.num_active()
        t0 = time.monotonic()
        iters = 0
        toks0 = self.stats.tokens_generated
        while self.num_active() > 0:
            self.step()
            iters += 1
        decode_t = time.monotonic() - t0
        tokens = self.stats.tokens_generated - toks0
        return {
            "prefill_time": prefill_t / max(n_admitted, 1),
            "decode_per_token": decode_t / max(iters, 1),
            "throughput": tokens / max(decode_t, 1e-9),
            "batch_size": float(n_admitted),
        }
