"""Continuous-batching LLM engine (the "LLM serving instance" of Def. 2.3).

Real-execution engine: actual JAX models (reduced configs on CPU; the same
code path jit-compiles for TPU), iteration-level scheduling a la
Orca/vLLM:

  * fixed slot array (``max_slots``) holding the running batch,
  * paged KV accounting via ``BlockManager`` (admission + preemption),
  * **chunked, length-bucketed prefill**: prompts are split into chunks of
    at most ``prefill_chunk_tokens``; every ``step()`` runs ONE chunk for
    all mid-prefill slots as a single batched jit call (chunk length padded
    to a power-of-two bucket so jit shapes stay bounded) and THEN a decode
    iteration for the fully-prefilled slots — a long batch-job prompt no
    longer stalls interactive decodes (SLOs-Serve / chunked-prefill
    co-scheduling),
  * ``step()`` = admit-from-pull-source, one prefill chunk round, one
    decode iteration for all decode-ready slots,
  * **device-resident hot loop**: the jitted decode / chunk calls DONATE
    the KV cache (``jax.jit(..., donate_argnums)``) so the page pool is
    updated in place instead of copied every iteration; the block table is
    maintained incrementally by ``BlockManager`` (persistent fixed-shape
    int32 array + version counter) and its device copy refreshed only when
    it changed; ``steps(k)`` fuses up to ``EngineConfig.decode_burst``
    decode iterations into ONE jitted ``lax.while_loop`` dispatch
    (device-side argmax, length increments and EOS / max-token finish
    flags accumulated in a mask) with a single host sync per burst —
    falling back to single-step whenever a slot is mid-prefill or the
    block pool is at the preemption edge,
  * request eviction with host-side KV/state snapshots (the paper's
    eviction LSO — resume skips prefill entirely; mid-prefill evictions
    resume from the last completed chunk),
  * model swapping (flush KV, replace weights; paper's swap LSO),
  * selectable attention backend: ``"xla"`` / ``"pallas"`` keep the dense
    per-slot KV arrays (Pallas kernels interpret on CPU, Mosaic on TPU);
    ``"paged-xla"`` / ``"paged-pallas"`` store KV as a single physical page
    pool ``(layers, num_blocks, KVH, block_size, D)`` addressed through the
    ``BlockManager`` block tables — the PagedAttention layout the paper's
    LSOs assume from their vLLM backend.  Paged mode makes KV capacity
    ``kv_blocks * block_size`` tokens SHARED across slots (vs
    ``max_slots * max_seq_len`` dense), eviction snapshots copy only the
    sequence's pages, and freed pages are physically reused by later
    admissions.  Token-for-token identical to the dense backends.

Backend support matrix (rows = engine capabilities; see
``models/attention.py`` for the kernel-level view):

  backend        KV layout       prefill chunk        decode
  "xla"          per-slot dense  jnp two-segment      jnp masked SDPA
  "pallas"       per-slot dense  jnp two-segment      Pallas blocked kernel
  "paged-xla"    page pool       stacked-gather SDPA  gather + masked SDPA
  "paged-pallas" page pool       fused paged-prefill  paged multi-page-tile
                                 Pallas kernel        Pallas kernel

  * dense backends: all archs, incl. SWA (rolling cache) and kv_quant;
    SSM/hybrid/enc-dec ride the legacy single-shot prefill.
  * paged backends: full-attention transformer archs with chunked prefill
    only (engine __init__ gates); kv_quant supported via int8 page pools
    with fused-dequant kernels; ``EngineConfig.pages_per_tile`` tunes the
    kernels' multi-page kv tiles (None = auto from block_size).
  * donation + burst apply to ALL four backends: every backend's decode /
    chunk jit call donates the cache (``EngineConfig.donate_buffers``,
    default on), and ``steps()`` bursts ``decode_burst`` iterations per
    dispatch token-identically to the single-step loop (KV blocks for the
    whole burst are reserved up front, so a burst can never write an
    unallocated page; completion timestamps within a burst collapse to
    the burst's host sync).
  * **prefix sharing** (``EngineConfig.prefix_sharing``, default on) is a
    paged-backend capability — dense per-slot KV has no physical pages to
    share, so the flag is inert on "xla"/"pallas".  On the paged backends
    admission matches the incoming prompt against the ``BlockManager``
    prefix index (full blocks published as their chunks complete) and
    attaches the hit chain refcounted instead of re-prefilling it:
    chunked prefill starts at the first unshared token, page writes only
    ever target private blocks (copy-on-write peels a shared tail block
    before any divergent write — ``_apply_cow`` runs the pending page
    copies before every dispatch), eviction pins shared blocks instead of
    freeing or copying them (snapshots hold only privately-owned pages),
    and ``fork_slot`` clones a running decode onto a free slot with zero
    page copies.  Token-for-token identical to ``prefix_sharing=False``
    on every backend; a pinned (shared) snapshot resumes only on the
    engine that evicted it — cross-engine mid-decode migration of a
    shared sequence raises, like cross-layout resume.

Dense cache pytrees have layout (layers/sites, batch, ...), so slot insert
/ extract are uniform ``tree_map``s over axis 1; paged caches have no
batch axis and are extracted/restored by page id instead.

**Cancellation contract** (``cancel_request`` / ``shed_slots`` — the async
front end's hooks, ``serving.frontend``):

  * ``cancel_request(req)`` terminates ``req`` wherever it lives: a
    resident slot is freed mid-decode or mid-prefill (pending COW copies
    are applied first so no queued page copy can land on a page the free
    list hands to a later admission), an eviction snapshot is discarded
    (releasing any shared-prefix pins on its source pool), and a request
    the engine has never seen is a no-op returning False.  On success the
    request is marked ``cancelled`` with ``completion_time`` stamped, its
    KV blocks are back on the free list (shared blocks: its refcount is
    dropped; the pages live on for the other sharers / the prefix index),
    and the slot is immediately admittable.  Cancellation between the
    dispatch that produced a token and the host sync that records it is
    safe: the hook runs on the orchestrator thread between ``steps()``
    calls, never concurrently with a dispatch.
  * ``shed_slots(should_shed, drop=)`` applies a predicate over the
    running batch: matching slots are EVICTED (snapshot to host, resumable
    later — ``drop=False``, the deferral policy) or CANCELLED outright
    (``drop=True``); the returned requests have ``_in_flight`` cleared so
    the virtual-queue owner can re-pull or account them.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import Request
from repro.models.model_factory import Model
from repro.serving.kv_cache import BlockManager

ATTENTION_BACKENDS = ("xla", "pallas", "paged-xla", "paged-pallas")


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_seq_len: int = 512
    block_size: int = 16
    kv_blocks: Optional[int] = None    # None => max_slots*max_seq_len worth
    eos_token: Optional[int] = None
    dtype: Any = jnp.float32
    # Chunked prefill: max prompt tokens processed per slot per step().
    # 0 disables chunking (legacy single-shot batch=1 prefill at admit).
    prefill_chunk_tokens: int = 128
    # Chunk-length padding buckets; None => powers of two up to
    # prefill_chunk_tokens.  Bounded buckets keep the number of distinct
    # jit shapes (and thus compiles) small.
    prefill_buckets: Optional[Tuple[int, ...]] = None
    # Serving attention backend: None follows the model config's
    # use_pallas_attention flag; "xla" / "pallas" force the jnp or Pallas
    # (flash / blocked-decode, interpret mode off-TPU) paths respectively.
    # "paged-xla" / "paged-pallas" switch the KV cache to a physically
    # paged block-table pool (full-attention transformer archs with
    # chunked prefill only).
    attention_backend: Optional[str] = None
    # KV pages per kernel grid step for the paged Pallas kernels (decode +
    # fused prefill-chunk): multi-page tiles keep MXU tiles full when
    # block_size is small.  None = auto-derive from block_size (targets
    # 128-row tiles); forwarded to the model config's paged_pages_per_tile.
    pages_per_tile: Optional[int] = None
    # Fused multi-step decode dispatch: ``steps()`` runs up to this many
    # decode iterations inside one jitted lax.while_loop (one host sync per
    # burst instead of per token).  1 = the single-step ``step()`` loop.
    decode_burst: int = 1
    # Donate the KV cache (and decode token array) into the jitted decode /
    # chunk calls so XLA updates the pool in place instead of copying it
    # every iteration.  Off only for A/B benchmarking (engine_bench.py).
    donate_buffers: bool = True
    # Maintain the (max_slots, max_blocks_per_seq) block table incrementally
    # inside BlockManager (refreshing the device copy only when it changed)
    # instead of rebuilding it in Python twice per step.  Off only for A/B
    # benchmarking against the seed behavior.
    incremental_block_table: bool = True
    # Run repro.analysis.invariants.check_engine at every step()/steps()
    # round boundary (BlockManager conservation, refcount accounting,
    # slot-table sync, per-slot length contracts).  Also forced on by
    # QLINT_INVARIANTS=1; QLINT_INVARIANTS_SAMPLE=N checks every Nth
    # round.  Debug aid — O(pool + slots) python per checked round.
    debug_invariants: bool = False
    # Refcounted prefix sharing + copy-on-write pages (paged backends only;
    # inert on the dense layouts, which have no physical pages to share).
    # Admission matches prompts against the BlockManager prefix index and
    # skips prefill for cached full blocks.  Off for A/B comparison — token
    # streams are identical either way, only pool usage / prefill work and
    # the prefix_* stats change.
    prefix_sharing: bool = True

    @property
    def paged(self) -> bool:
        return self.attention_backend is not None \
            and self.attention_backend.startswith("paged")

    def resolved_kv_blocks(self) -> int:
        if self.kv_blocks is not None:
            return self.kv_blocks
        return (self.max_slots * self.max_seq_len) // self.block_size

    def max_blocks_per_seq(self) -> int:
        return -(-self.max_seq_len // self.block_size)

    def resolved_buckets(self) -> Tuple[int, ...]:
        if self.prefill_buckets:
            buckets = sorted(self.prefill_buckets)
            if self.prefill_chunk_tokens > 0 \
                    and buckets[-1] < self.prefill_chunk_tokens:
                # buckets must cover the largest possible chunk, else the
                # padding falls back to exact lengths and the jit-shape
                # bound is lost
                buckets.append(self.prefill_chunk_tokens)
            return tuple(buckets)
        if self.prefill_chunk_tokens <= 0:
            return ()
        buckets = []
        b = 16
        while b < self.prefill_chunk_tokens:
            buckets.append(b)
            b *= 2
        buckets.append(self.prefill_chunk_tokens)
        return tuple(buckets)


@dataclasses.dataclass
class EngineStats:
    decode_iterations: int = 0
    prefills: int = 0
    prefill_chunks: int = 0
    evictions: int = 0
    resumes: int = 0
    model_swaps: int = 0
    tokens_generated: int = 0
    preemptions: int = 0
    decode_time: float = 0.0
    prefill_time: float = 0.0
    swap_time: float = 0.0
    # prefix sharing (paged backends with EngineConfig.prefix_sharing)
    prefix_lookups: int = 0        # fresh chunked admissions that probed
    prefix_hits: int = 0           # ... and attached a shared chain
    prefix_shared_blocks: int = 0  # blocks attached without re-prefill
    prefix_shared_tokens: int = 0  # prompt tokens skipped by prefill
    prompt_tokens_admitted: int = 0  # denominator for the hit-rate counters
    cow_copies: int = 0            # copy-on-write page copies applied
    forks: int = 0                 # fork_slot clones
    # async front-end hooks (frontend cancellation / overload shedding)
    cancellations: int = 0         # cancel_request frees (slot or snapshot)
    sheds: int = 0                 # shed_slots evict/drop actions
    # cross-engine snapshot migration (self-healing cluster lifecycle)
    migrations_out: int = 0        # snapshots made portable on request
    migrations_in: int = 0         # foreign snapshots resumed here


class ContinuousBatchingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 model_name: str = "default",
                 clock: Callable[[], float] = time.monotonic):
        if cfg.attention_backend not in ATTENTION_BACKENDS + (None,):
            raise ValueError(
                f"attention_backend must be one of {ATTENTION_BACKENDS} "
                f"or None, got {cfg.attention_backend!r}")
        self.cfg = cfg
        # Two explicit time bases.  ``self.clock`` stamps the REQUEST
        # LIFECYCLE (first_token_time, completion_time, redelivery
        # backoff gates) so virtual-clock drivers own the schedule;
        # ``self._wall`` is ALWAYS real wall time and feeds the
        # calibration stats (prefill_time / decode_time / swap_time),
        # which measure actual compute even when the lifecycle clock is
        # simulated.  Timed regions must never mix the two.
        self.clock = clock
        self._wall = time.monotonic
        # Serializes engine-internal state (slots, page pool, snapshots)
        # between the agent thread's rounds and cross-thread LSOs
        # (migration_sweep materialize, drain eviction).  The controller
        # only ever acquires it NON-blocking while holding its own lock
        # (see core/qlm.py), so lock order engine -> controller is the
        # one that may block and no cycle exists.
        self.lock = threading.RLock()
        self.paged = cfg.paged
        # sharing needs a physical page pool: inert on the dense layouts
        self.prefix_sharing = bool(cfg.prefix_sharing) and self.paged
        self.model = self._with_backend(model)
        self.params = params
        self.model_name = model_name
        self.stats = EngineStats()
        if self.paged:
            if self.model.init_paged_cache is None:
                raise ValueError(
                    f"attention_backend {cfg.attention_backend!r} requires an "
                    f"arch with pageable KV (got {self.model.cfg.arch_type})")
            if self.model.cfg.sliding_window is not None:
                raise ValueError(
                    "paged attention backends support full attention only "
                    "(rolling SWA page reuse is a ROADMAP follow-on)")
            if cfg.prefill_chunk_tokens <= 0:
                raise ValueError(
                    "paged attention backends require chunked prefill "
                    "(prefill_chunk_tokens > 0): the legacy single-shot "
                    "path writes per-slot dense caches")

        # prefix sharing keeps freed-but-indexed blocks cached so follow-up
        # turns (same leading tokens, submitted after the original request
        # finished) still match the chain
        self.block_mgr = BlockManager(cfg.resolved_kv_blocks(),
                                      cfg.block_size,
                                      cache_freed=self.prefix_sharing)
        if cfg.incremental_block_table:
            self.block_mgr.attach_slot_table(cfg.max_slots,
                                             cfg.max_blocks_per_seq())
        # persistent device copy of the slot block table, refreshed only
        # when BlockManager.table_version moves
        self._bt_device = None
        self._bt_version_seen = -1
        self.slots: List[Optional[Request]] = [None] * cfg.max_slots
        self.lengths = np.zeros(cfg.max_slots, np.int32)
        # prompt tokens already prefilled per slot; a slot is mid-prefill
        # while prefill_pos < prompt_len (decode-ready otherwise)
        self.prefill_pos = np.zeros(cfg.max_slots, np.int32)
        self.cache = self._init_cache()
        self.pull_source: Optional[Callable[[], Optional[Request]]] = None
        self.completed: List[Request] = []
        # requests whose eviction snapshot pins shared blocks in OUR pool:
        # before a pool reset (model swap) kills the pins, the pinned pages
        # are materialized into the snapshots so the requests stay
        # resumable (see _materialize_pinned_snapshots)
        self._pinned_snapshots: List[Request] = []
        self._pushback: Optional[Request] = None
        # requests that finished INSIDE admit() (legacy path, EOS/max_new on
        # the prefill token); drained into the next step()'s return value
        self._admit_completed: List[Request] = []

        self._jit_compute()

    def _with_backend(self, model: Model) -> Model:
        """Route the model's attention through the configured backend
        (None = keep the model config's own use_pallas_attention) and
        forward the paged-kernel tile tunable."""
        backend = self.cfg.attention_backend
        changes = {}
        if backend is not None:
            want = backend.endswith("pallas")
            if model.cfg.use_pallas_attention != want:
                changes["use_pallas_attention"] = want
        if self.cfg.pages_per_tile is not None \
                and model.cfg.paged_pages_per_tile != self.cfg.pages_per_tile:
            changes["paged_pages_per_tile"] = self.cfg.pages_per_tile
        if changes:
            from repro.models.model_factory import build_model
            return build_model(dataclasses.replace(model.cfg, **changes))
        return model

    def _init_cache(self):
        if self.paged:
            return self.model.init_paged_cache(
                self.cfg.resolved_kv_blocks(), self.cfg.block_size,
                self.cfg.dtype)
        return self.model.init_cache(self.cfg.max_slots, self.cfg.max_seq_len,
                                     self.cfg.dtype)

    def _jit_compute(self) -> None:
        # donate the cache (arg 1) — the page pool is the whole KV budget,
        # donating it lets XLA update it in place instead of copying it
        # every iteration — and the decode token array (arg 2), which is
        # consumed by the same-shaped next_tokens output.  The block table
        # (last paged arg) is NEVER donated: it is the persistent device
        # copy reused across steps.
        donate = (1, 2) if self.cfg.donate_buffers else ()
        chunk_donate = (1,) if self.cfg.donate_buffers else ()
        if self.paged:
            self._decode_fn = jax.jit(self._decode_paged_impl,
                                      donate_argnums=donate)
            self._chunk_fn = jax.jit(self._prefill_chunk_paged_impl,
                                     donate_argnums=chunk_donate)
        else:
            self._decode_fn = jax.jit(self._decode_impl, donate_argnums=donate)
            self._chunk_fn = jax.jit(self._prefill_chunk_impl,
                                     donate_argnums=chunk_donate)
        self._burst_fn = jax.jit(self._decode_burst_impl,
                                 donate_argnums=chunk_donate)
        # COW page copy: dst pages <- src pages across every pool leaf
        # (axis 1 = blocks).  Donated so XLA updates the pool in place.
        self._cow_fn = jax.jit(
            lambda cache, src, dst: jax.tree.map(
                lambda full: full.at[:, dst].set(full[:, src]), cache),
            donate_argnums=(0,) if self.cfg.donate_buffers else ())
        self._prefill_cache = {}  # per-length jitted single-shot prefill
        self._bt_device = None
        self._bt_version_seen = -1

    # ------------------------------------------------------------------
    # jitted compute
    # ------------------------------------------------------------------
    def _decode_impl(self, params, cache, tokens, lengths):
        logits, new_cache = self.model.decode_step(params, cache, tokens, lengths)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    def _prefill_chunk_impl(self, params, cache, tokens, starts, valid):
        logits, new_cache = self.model.prefill_chunk(params, cache, tokens,
                                                     starts, valid)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, new_cache

    def _decode_paged_impl(self, params, cache, tokens, lengths, block_table):
        logits, new_cache = self.model.decode_step_paged(
            params, cache, tokens, lengths, block_table)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    def _prefill_chunk_paged_impl(self, params, cache, tokens, starts, valid,
                                  block_table):
        logits, new_cache = self.model.prefill_chunk_paged(
            params, cache, tokens, starts, valid, block_table)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, new_cache

    def _decode_burst_impl(self, params, cache, tokens, lengths, remaining,
                           active, n_steps, block_table):
        """Up to ``decode_burst`` decode iterations in ONE device dispatch:
        a ``lax.while_loop`` carrying (tokens, lengths, remaining-new-token
        budgets, active mask, cache) with the argmax, length increments and
        EOS / max-token / max-seq-len finish flags all computed on device.
        Returns the (decode_burst, max_slots) token buffer (sentinel -1 for
        slots inactive at that iteration) and the final cache — ONE host
        sync per burst instead of one per token.

        ``n_steps`` is traced (bursts shrink near the KV-capacity edge
        without recompiling); the buffer width is the static
        ``cfg.decode_burst``.  The caller pre-reserves every block a full
        burst can write, so no iteration ever lands on an unallocated page.
        Finished slots keep re-writing their final token's k/v at their
        (frozen) last position — idempotent, and their pages are freed at
        the host sync.  ``block_table`` is None for the dense backends.
        """
        K = max(int(self.cfg.decode_burst), 1)
        max_seq = self.cfg.max_seq_len
        eos = self.cfg.eos_token

        def body(state):
            i, tokens, lengths, remaining, active, cache, out = state
            if self.paged:
                logits, cache = self.model.decode_step_paged(
                    params, cache, tokens, lengths, block_table)
            else:
                logits, cache = self.model.decode_step(
                    params, cache, tokens, lengths)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            produced = jnp.where(active, nxt, tokens)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(active, nxt, jnp.int32(-1)), i, axis=0)
            step = active.astype(jnp.int32)
            lengths = lengths + step
            remaining = remaining - step
            # mirror _finish_if_done exactly (post-increment conditions)
            fin = (remaining <= 0) | (lengths >= max_seq)
            if eos is not None:
                fin = fin | (produced == eos)
            return (i + 1, produced, lengths, remaining,
                    active & ~fin, cache, out)

        def cond(state):
            return (state[0] < n_steps) & jnp.any(state[4])

        out0 = jnp.full((K, self.cfg.max_slots), -1, jnp.int32)
        state = (jnp.int32(0), tokens, lengths, remaining, active, cache, out0)
        state = jax.lax.while_loop(cond, body, state)
        return state[6], state[5]

    def _block_table_array(self) -> np.ndarray:
        """From-scratch rebuild of the (max_slots, max_blocks_per_seq) int32
        block table (sentinel ``num_blocks`` for unallocated logical blocks
        and empty slots — writes dropped, reads clamped+masked).

        This is the REFERENCE path: the hot loop uses the incremental table
        ``BlockManager.slot_table()`` via ``_device_block_table`` and only
        falls back here when ``cfg.incremental_block_table`` is off (seed
        behavior, kept for A/B benchmarking).  The property suite asserts
        the two always agree."""
        sentinel = self.block_mgr.num_blocks
        bt = np.full((self.cfg.max_slots, self.cfg.max_blocks_per_seq()),
                     sentinel, np.int32)
        for i in self.active_slots():
            r = self.slots[i]
            if self.block_mgr.has(r.req_id):
                row = self.block_mgr.block_table(r.req_id)
                assert len(row) <= bt.shape[1], (len(row), bt.shape)
                bt[i, :len(row)] = row
        return bt

    def _device_block_table(self):
        """Device copy of the slot block table, re-uploaded only when the
        BlockManager's incremental table changed since the last dispatch
        (the seed rebuilt + re-uploaded the full table twice per step)."""
        if not self.cfg.incremental_block_table:
            return jnp.asarray(self._block_table_array())
        version = self.block_mgr.table_version
        if self._bt_device is None or self._bt_version_seen != version:
            # .copy(): the manager mutates its table in place and jnp.asarray
            # may alias host memory on CPU — the device copy must be a
            # snapshot of THIS version
            self._bt_device = jnp.asarray(self.block_mgr.slot_table().copy())
            self._bt_version_seen = version
        return self._bt_device

    def _prefill_one(self, prompt: np.ndarray, extras: Dict[str, Any]):
        """Prefill a single request (batch=1, exact length — SSM-state safe)."""
        L = len(prompt)
        key = (L,) + tuple(sorted(extras))
        if key not in self._prefill_cache:
            def fn(params, batch, cache):
                logits, new_cache = self.model.prefill(params, batch, cache)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return tok, new_cache
            self._prefill_cache[key] = jax.jit(fn)
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
        batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
        cache1 = self.model.init_cache(1, self.cfg.max_seq_len, self.cfg.dtype)
        tok, cache1 = self._prefill_cache[key](self.params, batch, cache1)
        return int(tok[0]), cache1

    # ------------------------------------------------------------------
    # slot plumbing
    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _insert_cache(self, slot_cache, b: int) -> None:
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, b].set(one[:, 0]), self.cache, slot_cache)

    def _extract_cache(self, b: int):
        # qlint: disable=host-sync-in-hot-path -- intended device->host copy: the eviction snapshot must leave the pool
        return jax.tree.map(lambda full: np.asarray(full[:, b]), self.cache)

    def _restore_cache(self, snapshot, b: int) -> None:
        self.cache = jax.tree.map(
            lambda full, snap: full.at[:, b].set(jnp.asarray(snap)),
            self.cache, snapshot)

    def _extract_pages(self, block_ids: List[int]):
        """Paged eviction snapshot: copy ONLY the given pages (axis 1 of
        each (layers, num_blocks, ...) pool leaf) to host memory — the
        physical reclamation the dense per-slot layout couldn't do.  Under
        prefix sharing the caller passes only the PRIVATE tail (shared
        blocks stay alive in the pool, pinned by the snapshot)."""
        bt = np.asarray(block_ids, np.int32)  # qlint: disable=host-sync-in-hot-path -- host list -> int32 index array, no device sync
        # qlint: disable=host-sync-in-hot-path -- intended device->host copy: paged eviction snapshot leaves the pool
        return jax.tree.map(lambda full: np.asarray(full[:, bt]), self.cache)

    def _restore_pages(self, snapshot, block_ids: List[int],
                       offset: int = 0) -> None:
        """Scatter snapshotted page contents into freshly allocated pages
        starting at logical position ``offset`` (the pinned shared prefix,
        already resident, precedes them).  The allocation may be LARGER
        than the snapshot (the resume also reserves the next decode step's
        slot); extra pages are written before they are ever read."""
        n_snap = jax.tree.leaves(snapshot)[0].shape[1]
        assert len(block_ids) - offset >= n_snap, \
            (len(block_ids), offset, n_snap)
        ids = jnp.asarray(np.asarray(block_ids[offset:offset + n_snap],  # qlint: disable=host-sync-in-hot-path -- host list -> device upload, no sync
                                     np.int32))
        self.cache = jax.tree.map(
            lambda full, snap: full.at[:, ids].set(jnp.asarray(snap)),
            self.cache, snapshot)

    def _apply_cow(self) -> None:
        """Apply pending copy-on-write page copies (BlockManager re-pointed
        the tables; the page CONTENTS move here) — must run before any
        dispatch that could write a COW destination page, and before an
        eviction snapshot reads one."""
        if not self.paged:
            return
        ops = self.block_mgr.take_cow_ops()
        if not ops:
            return
        # pad to a power-of-two width so _cow_fn compiles O(log max_ops)
        # distinct shapes, not one per pending-op count (a mid-serve
        # compile is exactly the host-side stall class the device-resident
        # loop removed).  Padding repeats the last real op: duplicate
        # scatter indices carrying IDENTICAL values are deterministic,
        # whereas an identity pad could collide with a real op on the same
        # destination page
        width = 1
        while width < len(ops):
            width *= 2
        pad = [ops[-1]] * (width - len(ops))
        src = jnp.asarray(np.asarray([s for s, _ in ops] + [p[0] for p in pad],  # qlint: disable=host-sync-in-hot-path -- host op list -> device upload, no sync
                                     np.int32))
        dst = jnp.asarray(np.asarray([d for _, d in ops] + [p[1] for p in pad],  # qlint: disable=host-sync-in-hot-path -- host op list -> device upload, no sync
                                     np.int32))
        self.cache = self._cow_fn(self.cache, src, dst)
        self.stats.cow_copies += len(ops)

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def decode_slots(self) -> List[int]:
        """Slots whose prefill is complete (participate in decode)."""
        return [i for i, r in enumerate(self.slots)
                if r is not None and self.prefill_pos[i] >= r.prompt_len]

    def prefilling_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots)
                if r is not None and self.prefill_pos[i] < r.prompt_len]

    def num_active(self) -> int:
        return len(self.active_slots())

    def running_requests(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    # ------------------------------------------------------------------
    # admission (request pulling LSO actuation point)
    # ------------------------------------------------------------------
    def _owed_prefill_blocks(self) -> int:
        """KV blocks committed to mid-prefill slots but not yet allocated
        (admission reserves only the first chunk; the rest arrives
        chunk-by-chunk via ``BlockManager.extend``)."""
        owed = 0
        for i in self.prefilling_slots():
            r = self.slots[i]
            have = len(self.block_mgr.block_table(r.req_id)) \
                if self.block_mgr.has(r.req_id) else 0
            owed += max(self.block_mgr.blocks_needed(r.prompt_len + 1) - have, 0)
        return owed

    def _usable_pins(self, snap) -> Optional[List[int]]:
        """The pinned shared blocks of an eviction snapshot, IF they live in
        THIS engine's current pool (owner + epoch match).  ``[]`` for an
        unshared snapshot; None when the pins belong to another pool (or a
        pool epoch that has since been reset) — the prefix KV is then
        unreachable here."""
        pinned = snap.get("pinned") or []
        if not pinned:
            return []
        if snap.get("pin_owner") is self.block_mgr \
                and snap.get("pin_epoch") == self.block_mgr.epoch:
            return pinned
        return None

    def _discard_snapshot(self, req: Request) -> None:
        """Drop a snapshot, releasing any pins it holds on its SOURCE pool
        (the snapshot carries its owner, so this is safe cross-engine;
        stale epochs no-op inside release_pins)."""
        snap, req.snapshot = req.snapshot, None
        if snap and snap.get("pinned"):
            snap["pin_owner"].release_pins(snap["pinned"], snap["pin_epoch"])

    def can_admit(self, req: Request) -> bool:
        if self._free_slot() is None:
            return False
        if self.paged and req.extras:
            # modality extras ride the legacy single-shot prefill, which has
            # no paged variant: refuse (pull loop hands the request back via
            # pushback) instead of exploding inside admit()
            return False
        snap = req.snapshot
        shared_blocks = 0
        if snap is not None:
            pins = self._usable_pins(snap)
            if pins is None and req.generated > 0:
                # shared blocks pinned in another pool: not resumable here
                # mid-decode (admit would raise) — let the pull loop hand
                # the request back instead
                return False
            shared_blocks = len(pins or ())
        elif self.prefix_sharing and self._use_chunked(req.extras or {}):
            # admission-time prefix match: LIVE indexed chains arrive from
            # the pool, not the free list.  Freed-but-cached matches (ref 0)
            # don't count — share_prefix revives them OUT of the allocatable
            # pool, so capacity-wise they cost as much as a fresh block.
            shared_blocks = sum(
                1 for b in self.block_mgr.match_prefix(req.prompt_tokens)
                if self.block_mgr.ref_count(b) >= 1)
        if snap is not None \
                and snap.get("prefill_pos", req.prompt_len) >= req.prompt_len:
            # decode-phase resume: only the snapshotted tokens plus the next
            # decode step's KV slot are needed (a request evicted at the
            # max_seq_len boundary must stay re-admittable so it can emit
            # its final token)
            need = snap["length"] + 1
        else:
            need = req.prompt_len + req.generated + 1
        if need > self.cfg.max_seq_len:
            return False
        # conservative: the WHOLE prompt must be coverable up front — counting
        # blocks still owed to other mid-prefill slots — even though chunked
        # prefill allocates chunk-by-chunk; otherwise two long prompts could
        # both pass the check and one would be guaranteed to preempt
        # mid-prefill.
        return self.block_mgr.can_allocate(
            need, reserve_blocks=self._owed_prefill_blocks(),
            shared_blocks=shared_blocks)

    def _use_chunked(self, extras: Dict[str, Any]) -> bool:
        return (self.cfg.prefill_chunk_tokens > 0
                and self.model.prefill_chunk is not None
                and not extras)

    def _chunk_quantum(self) -> int:
        """Effective chunk size: clamped to the rolling SWA cache length so
        a single chunk can never write the same cache slot twice (duplicate
        scatter indices resolve nondeterministically)."""
        C = self.cfg.prefill_chunk_tokens
        w = self.model.cfg.sliding_window
        if C > 0 and w is not None:
            C = min(C, min(self.cfg.max_seq_len, w))
        return C

    def admit(self, req: Request, extras: Optional[Dict[str, Any]] = None) -> bool:
        """Start prefill for (or snapshot-restore) ``req`` in a free slot.

        On the chunked path admission only reserves the first chunk's KV
        blocks and marks the slot mid-prefill; the actual compute happens
        inside subsequent ``step()`` calls, interleaved with decode.
        """
        slot = self._free_slot()
        if slot is None or not self.can_admit(req):
            return False
        t0 = self._wall()
        ex = extras or req.extras or {}
        my_layout = "paged" if self.paged else "dense"
        if req.snapshot is not None \
                and req.snapshot.get("layout", "dense") != my_layout:
            # snapshot taken under the OTHER KV layout: page contents can't
            # be transplanted across layouts.  Recompute the prefill when
            # nothing was generated yet; past that the generated tokens'
            # KV is unrecoverable.
            if req.generated == 0:
                self._discard_snapshot(req)
            else:
                raise ValueError(
                    f"cannot resume a {req.snapshot.get('layout', 'dense')} "
                    f"KV snapshot on a {my_layout} engine mid-decode")
        if req.snapshot is not None \
                and self._usable_pins(req.snapshot) is None:
            # the snapshot's shared-prefix blocks are STILL pinned in
            # another engine's pool (or an epoch that has been reset):
            # only the private pages travelled with the snapshot, so the
            # prefix KV is unreachable here.  The migration path
            # (materialize_snapshot on the owner, driven by
            # QLMController.migration_sweep) makes such snapshots
            # portable BEFORE they reach a foreign engine; recompute when
            # nothing was generated yet (the discard releases the
            # foreign pins).
            if req.generated == 0:
                self._discard_snapshot(req)
            else:
                raise ValueError(
                    "cannot resume a live-pinned KV snapshot outside the "
                    "engine that evicted it mid-decode (materialize it "
                    "first: cross-engine migration)")
        if req.snapshot is not None \
                and req.snapshot.get("prefill_pos", req.prompt_len) < req.prompt_len \
                and not self._use_chunked(ex):
            # mid-prefill snapshot but THIS engine can't continue chunking
            # (chunking disabled, or the arch has no prefill_chunk): drop it
            # and recompute the full prefill instead of spinning on a
            # zero-token chunk round
            self._discard_snapshot(req)
        if req.snapshot is not None:
            # eviction resume: restore KV/state, no prefill recompute.
            # Mid-prefill snapshots resume chunking from the last chunk.
            snap = req.snapshot
            length = int(snap["length"])
            ppos = int(snap.get("prefill_pos", req.prompt_len))
            if ppos >= req.prompt_len:
                # decode-phase: cover the snapshotted tokens AND the next
                # decode step's write slot (kv_tokens can be one short when
                # the eviction was an append_token-failure preemption)
                kv_tokens = int(snap.get("kv_tokens", length + 1))
                alloc_tokens = max(kv_tokens, length + 1)
            else:
                alloc_tokens = int(snap.get("kv_tokens", ppos))
            pinned = self._usable_pins(snap) or []
            if pinned:
                # the shared prefix never left the pool (snapshot-pinned):
                # the pins transfer back to the sequence, only the private
                # tail below is re-scattered from host memory
                blocks = self.block_mgr.resume_pinned(req.req_id, pinned,
                                                      alloc_tokens)
            else:
                blocks = self.block_mgr.allocate(req.req_id, alloc_tokens)
            self.block_mgr.bind_slot(req.req_id, slot)
            if self.paged:
                self._restore_pages(snap["cache"], blocks,
                                    offset=len(pinned))
            else:
                self._restore_cache(snap["cache"], slot)
            self.lengths[slot] = length
            self.prefill_pos[slot] = ppos
            if snap.get("pin_owner") is not None \
                    and snap.get("pin_owner") is not self.block_mgr:
                # the snapshot was taken in ANOTHER engine's pool and
                # arrived portable (materialized): a completed migration
                self.stats.migrations_in += 1
            req.snapshot = None  # pins were transferred, not released
            self.stats.resumes += 1
            self.slots[slot] = req
        elif self._use_chunked(ex):
            shared: List[int] = []
            if self.prefix_sharing:
                self.stats.prefix_lookups += 1
                shared = self.block_mgr.match_prefix(req.prompt_tokens)
            # first unshared token: chunked prefill starts here (the match
            # is capped at prompt_len - 1, so the final chunk always has at
            # least one real token and produces the first-token logits)
            start = len(shared) * self.cfg.block_size
            first = min(self._chunk_quantum(), req.prompt_len - start)
            if shared:
                self.block_mgr.share_prefix(req.req_id, start + first, shared)
                self.stats.prefix_hits += 1
                self.stats.prefix_shared_blocks += len(shared)
                self.stats.prefix_shared_tokens += start
            else:
                self.block_mgr.allocate(req.req_id, first)
            # unconditional: a re-admission that missed the cache (e.g. a
            # recompute on another engine) must clear any stale hit record
            req.prefix_shared_tokens = start
            self.stats.prompt_tokens_admitted += req.prompt_len
            self.block_mgr.bind_slot(req.req_id, slot)
            self.prefill_pos[slot] = start
            self.lengths[slot] = start
            self.slots[slot] = req
        else:
            if self.paged:
                # only reachable by an explicit admit(req, extras={...})
                # call — pull-source requests with req.extras are refused in
                # can_admit above
                raise ValueError(
                    "paged attention backends have no legacy single-shot "
                    "prefill path (modality extras and non-chunking archs "
                    "need a dense backend)")
            # legacy single-shot path (SSM/hybrid/enc-dec state carry, and
            # modality extras that must ride the full-prompt prefill).
            # Compute first — a raising prefill must leave the engine clean.
            tok, cache1 = self._prefill_one(np.asarray(req.prompt_tokens), ex)  # qlint: disable=host-sync-in-hot-path -- host prompt list -> array for the one-shot prefill path
            self.slots[slot] = req
            self._insert_cache(cache1, slot)
            self.lengths[slot] = req.prompt_len
            self.prefill_pos[slot] = req.prompt_len
            self.block_mgr.allocate(req.req_id, req.prompt_len + 1)
            self.block_mgr.bind_slot(req.req_id, slot)
            now = self.clock()
            if req.first_token_time is None:
                req.first_token_time = now
            req.output_tokens.append(tok)
            req.generated += 1
            self.stats.prefills += 1
            # same first-token completion check as the chunked path (EOS on
            # the prefill token / max_new_tokens == 1) — may free the slot.
            # Lands in self.completed immediately; the _admit_completed
            # buffer lets the next step() also RETURN it.
            n0 = len(self._admit_completed)
            self._finish_if_done(slot, tok, now, self._admit_completed)
            self.completed.extend(self._admit_completed[n0:])
        self.stats.prefill_time += self._wall() - t0
        return True

    # ------------------------------------------------------------------
    # eviction LSO
    # ------------------------------------------------------------------
    def evict_slot(self, slot: int) -> Request:
        """Snapshot the slot's KV/state to host memory and free it.

        TPU adaptation of the paper's async GPU→CPU copy: ``device_get`` of
        the slot slice (the engine overlaps this with the next decode
        iteration when dispatch is async).  Mid-prefill slots keep their
        chunk progress in the snapshot and resume without recompute.
        """
        req = self.slots[slot]
        assert req is not None
        kv_tokens = self.block_mgr.seq_tokens(req.req_id) \
            if self.block_mgr.has(req.req_id) else 0
        if self.paged:
            # pending COW copies must land before the snapshot reads pages
            self._apply_cow()
            # shared leading blocks are NOT freed and NOT copied: the
            # departing sequence's reference becomes a snapshot pin, so the
            # chain survives in the pool (and stays prefix-matchable) even
            # if every other sharer finishes before this request resumes.
            # Only the privately-owned tail pages travel to host memory.
            pinned, private = self.block_mgr.evict_split(req.req_id)
            cache_snap = self._extract_pages(private)
        else:
            pinned = []
            cache_snap = self._extract_cache(slot)
            self.block_mgr.free(req.req_id)
        req.snapshot = {
            "cache": cache_snap,
            "length": int(self.lengths[slot]),
            "prefill_pos": int(self.prefill_pos[slot]),
            # blocks to re-allocate on resume (paged restore needs the page
            # count to match; dense resume keeps the same accounting)
            "kv_tokens": kv_tokens,
            "layout": "paged" if self.paged else "dense",
            # prefix-sharing pin bookkeeping (empty without sharing)
            "pinned": pinned,
            "pin_owner": self.block_mgr,
            "pin_epoch": self.block_mgr.epoch,
            "shared_tokens": len(pinned) * self.cfg.block_size,
        }
        req.n_evictions += 1
        if pinned:
            # opportunistic purge: entries whose snapshot was consumed by a
            # resume (or discarded) need no materialization at swap time
            self._pinned_snapshots = [
                r for r in self._pinned_snapshots
                if r.snapshot is not None and r.snapshot.get("pinned")]
            self._pinned_snapshots.append(req)
        self.slots[slot] = None
        self.lengths[slot] = 0
        self.prefill_pos[slot] = 0
        self.stats.evictions += 1
        return req

    def evict_request(self, req_id: int) -> Optional[Request]:
        for i, r in enumerate(self.slots):
            if r is not None and r.req_id == req_id:
                return self.evict_slot(i)
        return None

    def flush(self) -> List[Request]:
        """Evict everything (used before a model swap)."""
        return [self.evict_slot(i) for i in self.active_slots()]

    # ------------------------------------------------------------------
    # cancellation + shedding hooks (async front end; contract in the
    # module docstring)
    # ------------------------------------------------------------------
    def _cancel_slot(self, slot: int) -> Request:
        """Free a resident slot WITHOUT a snapshot: the request is done
        (cancelled), so its KV pages go straight back to the free list.
        Pending COW copies must land first — a queued (src, dst) page copy
        whose dst this free releases would otherwise overwrite a page a
        later admission already owns."""
        req = self.slots[slot]
        assert req is not None, slot
        if self.paged:
            self._apply_cow()
        self.block_mgr.free(req.req_id)
        self.slots[slot] = None
        self.lengths[slot] = 0
        self.prefill_pos[slot] = 0
        req._in_flight = False
        req.cancelled = True
        if req.completion_time is None:
            req.completion_time = self.clock()
        self.stats.cancellations += 1
        return req

    def cancel_request(self, req: Request) -> bool:
        """Terminate ``req`` wherever it lives in THIS engine: resident
        slot (freed mid-decode/mid-prefill) or eviction snapshot
        (discarded, shared-prefix pins released).  Returns False when the
        engine holds no state for it (still queued elsewhere — the caller
        marks it cancelled itself)."""
        for i, r in enumerate(self.slots):
            if r is not None and r.req_id == req.req_id:
                self._cancel_slot(i)
                return True
        if req.snapshot is not None:
            self._discard_snapshot(req)
            req.cancelled = True
            if req.completion_time is None:
                req.completion_time = self.clock()
            self.stats.cancellations += 1
            return True
        return False

    def shed_slots(self, should_shed: Callable[[Request], bool],
                   drop: bool = False) -> List[Request]:
        """Overload shedding over the running batch: every active slot
        whose request matches ``should_shed`` is evicted (``drop=False``:
        snapshot to host, resumable when pressure clears) or cancelled
        outright (``drop=True``: KV freed, ``req.shed`` marked).  Returns
        the shed requests with ``_in_flight`` cleared."""
        out: List[Request] = []
        for i in list(self.active_slots()):
            req = self.slots[i]
            if req is None or not should_shed(req):
                continue
            if drop:
                self._cancel_slot(i)
                req.shed = True
            else:
                self.evict_slot(i)
                req._in_flight = False
            self.stats.sheds += 1
            out.append(req)
        return out

    def abandon(self) -> List[Request]:
        """Crash salvage (``serving.faults`` / ``QLMController.mark_dead``):
        reclaim every resident request WITHOUT stamping it terminal — the
        requests go back to the global queue for redelivery, so unlike
        ``_cancel_slot`` this sets no ``cancelled`` / ``completion_time``.
        Host-side bookkeeping only: the pool's contents are garbage after
        a crash, so no device compute runs, and pending COW page copies
        are dropped with the pool (their destinations are freed here, not
        handed to a future owner).  Returns the abandoned requests —
        resident slots plus any pushback limbo — with ``_in_flight``
        cleared and BlockManager accounting conserved (every allocation
        freed)."""
        out: List[Request] = []
        self.block_mgr._cow_ops.clear()
        for i in self.active_slots():
            req = self.slots[i]
            self.block_mgr.free(req.req_id)
            self.slots[i] = None
            self.lengths[i] = 0
            self.prefill_pos[i] = 0
            req._in_flight = False
            out.append(req)
        pushed = self.take_pushback()
        if pushed is not None:
            pushed._in_flight = False
            out.append(pushed)
        return out

    def _materialize_one(self, req: Request) -> bool:
        """Promote one still-live pinned snapshot to a self-contained one:
        copy the pinned pages' CONTENTS into the snapshot (prepended
        before the private tail) and release the pins.  After this the
        snapshot is PORTABLE: any engine with the same KV layout resumes
        it token-identically (the cross-engine migration primitive).
        Returns False when there is nothing to save (snapshot resumed /
        discarded / pinned elsewhere / stale epoch)."""
        snap = req.snapshot
        if not snap or not snap.get("pinned") \
                or snap.get("pin_owner") is not self.block_mgr \
                or snap.get("pin_epoch") != self.block_mgr.epoch:
            return False
        pinned = snap["pinned"]
        shared_pages = self._extract_pages(pinned)
        snap["cache"] = jax.tree.map(
            lambda shared, private: np.concatenate([shared, private],
                                                   axis=1),
            shared_pages, snap["cache"])
        self.block_mgr.release_pins(pinned, snap["pin_epoch"])
        snap["pinned"] = []
        return True

    def materialize_snapshot(self, req: Request) -> bool:
        """Cross-engine migration hook (``QLMController.migration_sweep``
        / ``drain_instance``): make ``req``'s eviction snapshot portable
        so a DIFFERENT engine can resume it.  Single-request form of
        ``_materialize_pinned_snapshots``; the request drops out of this
        engine's pinned-snapshot ledger once its pins are gone."""
        out = self._materialize_one(req)
        if out:
            self.stats.migrations_out += 1
            self._pinned_snapshots = [
                r for r in self._pinned_snapshots
                if r.snapshot is not None and r.snapshot.get("pinned")]
        return out

    def _materialize_pinned_snapshots(self) -> None:
        """Promote every still-live pinned snapshot to a self-contained one
        (see ``_materialize_one``).  Must run while the pool buffers are
        still alive — called before a pool reset (model swap) would kill
        the pins, so a request evicted with a shared prefix stays
        resumable after the engine swaps back to its model (the
        pre-sharing behavior)."""
        for req in self._pinned_snapshots:
            self._materialize_one(req)
        self._pinned_snapshots = []

    # ------------------------------------------------------------------
    # fork (parallel-sampling style sequence cloning)
    # ------------------------------------------------------------------
    def fork_slot(self, slot: int) -> Optional[Request]:
        """Clone a decode-phase request into a free slot, sharing EVERY KV
        page with the source (refcounts, zero page copies; the manager
        copy-on-writes a partial tail block so the two decodes never
        scatter into the same page — the copy lands at the next dispatch).
        Greedy decoding makes the clone deterministic: it continues exactly
        as the source would.  Returns None when no slot is free; raises
        OutOfBlocksError when the tail COW can't get a block.  Paged
        backends with ``prefix_sharing`` only."""
        if not self.prefix_sharing:
            raise ValueError(
                "fork_slot requires a paged attention backend with "
                "EngineConfig.prefix_sharing enabled")
        src = self.slots[slot]
        assert src is not None, slot
        if self.prefill_pos[slot] < src.prompt_len:
            raise ValueError("cannot fork a mid-prefill slot")
        new_slot = self._free_slot()
        if new_slot is None:
            return None
        clone = Request(
            prompt_tokens=list(src.prompt_tokens), model=src.model,
            slo=src.slo, arrival_time=src.arrival_time,
            max_new_tokens=src.max_new_tokens, slo_class=src.slo_class,
            priority=src.priority)
        clone.output_tokens = list(src.output_tokens)
        clone.generated = src.generated
        clone.first_token_time = src.first_token_time
        self.block_mgr.fork(src.req_id, clone.req_id)
        self.block_mgr.bind_slot(clone.req_id, new_slot)
        self.slots[new_slot] = clone
        self.lengths[new_slot] = self.lengths[slot]
        self.prefill_pos[new_slot] = self.prefill_pos[slot]
        self.stats.forks += 1
        return clone

    # ------------------------------------------------------------------
    # model swapping LSO
    # ------------------------------------------------------------------
    def swap_model(self, model: Model, params, model_name: str) -> List[Request]:
        t0 = self._wall()
        evicted = self.flush()
        # swapped-out requests' snapshots belong to the OLD model: drop them
        # (their KV is meaningless under the new weights; discard releases
        # any prefix-sharing pins before the pool reset below)
        for r in evicted:
            self._discard_snapshot(r)
        # EARLIER evictions' snapshots stay valid (the VQ re-feeds them only
        # when their model is loaded again): the pool reset below would kill
        # their pins, so copy the pinned page contents into the snapshots
        # while the old pool buffers are still alive
        self._materialize_pinned_snapshots()
        self.model = self._with_backend(model)
        self.params = params
        self.model_name = model_name
        if self.paged and self.model.init_paged_cache is None:
            raise ValueError(
                f"cannot swap a {self.model.cfg.arch_type} model into a "
                "paged-backend engine (no pageable KV)")
        self.cache = self._init_cache()
        self.block_mgr.reset()
        self._jit_compute()
        self.stats.model_swaps += 1
        self.stats.swap_time += self._wall() - t0
        return evicted

    # ------------------------------------------------------------------
    # one iteration
    # ------------------------------------------------------------------
    def take_pushback(self) -> Optional[Request]:
        r, self._pushback = self._pushback, None
        return r

    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.resolved_buckets():
            if n <= b:
                return b
        return n

    def _finish_if_done(self, slot: int, tok: int, now: float,
                        done: List[Request]) -> bool:
        req = self.slots[slot]
        eos = (self.cfg.eos_token is not None and tok == self.cfg.eos_token)
        # capacity finish fires at max_seq_len, NOT max_seq_len - 1: a slot
        # at lengths == max_seq_len - 1 still has one legal decode step
        # (its write lands at cache slot max_seq_len - 1) whose token must
        # be emitted before the slot retires — the final token itself
        # needs no KV slot because nothing attends after it.
        if eos or req.generated >= req.max_new_tokens \
                or self.lengths[slot] >= self.cfg.max_seq_len:
            req.completion_time = now
            done.append(req)
            self.block_mgr.free(req.req_id)
            self.slots[slot] = None
            self.lengths[slot] = 0
            self.prefill_pos[slot] = 0
            return True
        return False

    def _prefill_chunk_round(self, done: List[Request]) -> None:
        """One chunk of prefill for EVERY mid-prefill slot, batched into a
        single jit call padded to the smallest covering length bucket."""
        work = self.prefilling_slots()
        if not work:
            return
        t0 = self._wall()
        C = self._chunk_quantum()
        chunks: Dict[int, Tuple[np.ndarray, int, bool]] = {}
        for i in work:
            req = self.slots[i]
            pos = int(self.prefill_pos[i])
            n = min(C, req.prompt_len - pos)
            final = pos + n >= req.prompt_len
            # chunk-granular KV growth (+1 slot for the first decode token
            # on the final chunk, mirroring single-shot accounting)
            need = req.prompt_len + 1 if final else pos + n
            if not self.block_mgr.extend(req.req_id, need):
                # mid-prefill OOM: preempt; the snapshot keeps chunk progress
                # and the request becomes re-pullable (sim _evict_seq parity)
                self.stats.preemptions += 1
                self.evict_slot(i)
                req._in_flight = False
                continue
            chunk = np.asarray(req.prompt_tokens[pos:pos + n], np.int32)  # qlint: disable=host-sync-in-hot-path -- host prompt slice -> chunk array, no device sync
            chunks[i] = (chunk, n, final)
        if not chunks:
            return
        # COW copies from the extends above (shared partial tails) must
        # land before this dispatch writes the destination pages
        self._apply_cow()
        bucket = self._bucket_for(max(n for _, n, _ in chunks.values()))
        tokens = np.zeros((self.cfg.max_slots, bucket), np.int32)
        starts = np.zeros(self.cfg.max_slots, np.int32)
        valid = np.zeros(self.cfg.max_slots, np.int32)
        for i, (chunk, n, _) in chunks.items():
            tokens[i, :n] = chunk
            starts[i] = self.prefill_pos[i]
            valid[i] = n
        if self.paged:
            # table refreshed AFTER the extends above so it names this
            # chunk's freshly allocated pages
            toks_out, self.cache = self._chunk_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(starts), jnp.asarray(valid),
                self._device_block_table())
        else:
            toks_out, self.cache = self._chunk_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(starts), jnp.asarray(valid))
        # sync INSIDE the timed region: np.asarray(toks_out) alone only
        # waits for the token array, leaving the cache update in flight —
        # prefill_time would otherwise time async dispatch, not compute
        # (and RWT calibration via profile() would under-report)
        jax.block_until_ready(self.cache)  # qlint: disable=host-sync-in-hot-path -- documented timed-region sync: one per chunk round, feeds prefill_time / RWT calibration
        toks_out = np.asarray(toks_out)  # qlint: disable=host-sync-in-hot-path -- the round's single device->host result copy, inside the timed region
        self.stats.prefill_chunks += 1
        now = self.clock()
        for i, (_, n, final) in chunks.items():
            req = self.slots[i]
            self.prefill_pos[i] += n
            self.lengths[i] = self.prefill_pos[i]
            if self.prefix_sharing:
                # publish the prompt blocks this chunk completed: later
                # admissions with the same leading tokens attach to these
                # pages instead of re-prefilling them
                self.block_mgr.register_prefix(
                    req.req_id, req.prompt_tokens, int(self.prefill_pos[i]))
            if final:
                tok = int(toks_out[i])
                if req.first_token_time is None:
                    req.first_token_time = now
                req.output_tokens.append(tok)
                req.generated += 1
                self.stats.prefills += 1
                self._finish_if_done(i, tok, now, done)
        self.stats.prefill_time += self._wall() - t0

    def _decode_round(self, done: List[Request]) -> None:
        active = self.decode_slots()
        if not active:
            return
        t0 = self._wall()
        # pending COW copies (previous round's append_token, fork_slot)
        # must land before this dispatch writes the destination pages
        self._apply_cow()
        tokens = np.zeros(self.cfg.max_slots, np.int32)
        for i in active:
            tokens[i] = self.slots[i].output_tokens[-1] if self.slots[i].output_tokens \
                else self.slots[i].prompt_tokens[-1]
        if self.paged:
            next_tokens, self.cache = self._decode_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.lengths),
                self._device_block_table())
        else:
            next_tokens, self.cache = self._decode_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.lengths))
        # sync the cache too (see _prefill_chunk_round): decode_time feeds
        # the RWT estimator's decode_per_token via profile()
        jax.block_until_ready(self.cache)  # qlint: disable=host-sync-in-hot-path -- documented timed-region sync: one per decode round, feeds decode_time / RWT
        next_tokens = np.asarray(next_tokens)  # qlint: disable=host-sync-in-hot-path -- the round's single device->host result copy, inside the timed region
        self.stats.decode_iterations += 1
        self.stats.decode_time += self._wall() - t0

        now = self.clock()
        for i in active:
            req = self.slots[i]
            # record the token FIRST: the decode step that produced it has
            # already written its KV (at slot lengths), so neither a finish
            # nor an OOM preemption below may drop it.
            self.lengths[i] += 1
            tok = int(next_tokens[i])
            req.output_tokens.append(tok)
            req.generated += 1
            self.stats.tokens_generated += 1
            if req.first_token_time is None:
                req.first_token_time = now
            if self._finish_if_done(i, tok, now, done):
                continue
            # reserve the NEXT decode step's KV slot; preempt on OOM
            # (vLLM-style) — the just-produced token rides along in the
            # eviction snapshot instead of being recomputed on resume.
            if not self.block_mgr.append_token(req.req_id):
                self.stats.preemptions += 1
                self.evict_slot(i)
                req._in_flight = False

    def _plan_burst(self, active: List[int], k: int) -> int:
        """Largest burst width n <= k whose KV writes are FULLY coverable by
        the pool right now: each slot needs its allocation extended to
        ``lengths + min(n, rem) + 1`` tokens (every in-burst write plus the
        surviving slots' next-step reservation, capped at max_seq_len —
        a slot that retires at the boundary writes nothing past it).
        Returns 0 when not even n=2 fits — the caller falls back to the
        single-step round, whose per-token append/preempt logic owns the
        pool-exhaustion endgame (vLLM-style preemption parity).

        Under prefix sharing a slot whose partial tail block is still
        shared (refcount > 1) needs ONE extra free block: ``extend`` will
        copy-on-write the tail before the burst may scatter into it."""
        rem, cur = {}, {}
        cow_extra = 0
        for i in active:
            r = self.slots[i]
            rem[i] = min(r.max_new_tokens - r.generated,
                         self.cfg.max_seq_len - int(self.lengths[i]))
            cur[i] = len(self.block_mgr.block_table(r.req_id))
            if self.prefix_sharing \
                    and self.block_mgr.append_needs_cow(r.req_id):
                cow_extra += 1

        def blocks_short(n: int) -> int:
            need = cow_extra
            for i in active:
                tokens = min(int(self.lengths[i]) + min(n, rem[i]) + 1,
                             self.cfg.max_seq_len)
                need += max(self.block_mgr.blocks_needed(tokens) - cur[i], 0)
            return need

        n = max(k, 0)
        free = self.block_mgr.free_blocks
        while n > 1 and blocks_short(n) > free:
            n -= 1
        if n <= 1:
            return 0
        for i in active:
            tokens = min(int(self.lengths[i]) + min(n, rem[i]) + 1,
                         self.cfg.max_seq_len)
            ok = self.block_mgr.extend(self.slots[i].req_id, tokens)
            assert ok, (i, tokens)  # blocks_short(n) <= free guarantees it
        return n

    def _decode_burst_round(self, done: List[Request], k: int) -> None:
        """Fused decode: one jitted dispatch covering up to ``k`` decode
        iterations (device-side argmax + finish masks, single host sync),
        then replay the per-token bookkeeping from the burst's token
        buffer.  Token-identical to running ``_decode_round`` k times: the
        per-slot decode depends only on that slot's own cache/lengths, and
        the finish conditions are evaluated with the same post-increment
        convention on device and host."""
        active = self.decode_slots()
        if not active:
            return
        n = self._plan_burst(active, min(k, max(self.cfg.decode_burst, 1)))
        if n == 0:
            # pool at the preemption edge: the seed single-step logic owns
            # OOM preemption ordering
            self._decode_round(done)
            return
        t0 = self._wall()
        # COW copies from _plan_burst's extends (and any earlier fork /
        # append) must land before the fused loop writes those pages
        self._apply_cow()
        tokens = np.zeros(self.cfg.max_slots, np.int32)
        remaining = np.zeros(self.cfg.max_slots, np.int32)
        active_mask = np.zeros(self.cfg.max_slots, bool)
        for i in active:
            r = self.slots[i]
            tokens[i] = r.output_tokens[-1] if r.output_tokens \
                else r.prompt_tokens[-1]
            remaining[i] = r.max_new_tokens - r.generated
            active_mask[i] = True
        bt = self._device_block_table() if self.paged else None
        out, self.cache = self._burst_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.lengths), jnp.asarray(remaining),
            jnp.asarray(active_mask), jnp.int32(n), bt)
        jax.block_until_ready(self.cache)  # qlint: disable=host-sync-in-hot-path -- documented timed-region sync: THE single per-burst host sync the device-resident loop budgets for
        out = np.asarray(out)  # qlint: disable=host-sync-in-hot-path -- the burst's single device->host result copy, inside the timed region
        executed = int((out >= 0).any(axis=1).sum())
        self.stats.decode_iterations += executed
        self.stats.decode_time += self._wall() - t0

        now = self.clock()
        for i in active:
            req = self.slots[i]
            for j in range(executed):
                tok = int(out[j, i])
                if tok < 0:
                    break  # slot went inactive on device at iteration j
                self.lengths[i] += 1
                req.output_tokens.append(tok)
                req.generated += 1
                self.stats.tokens_generated += 1
                if req.first_token_time is None:
                    req.first_token_time = now
                if self._finish_if_done(i, tok, now, done):
                    break
            else:
                # survived the whole burst: the up-front reservation left
                # exactly the single-step invariant (lengths + 1 tokens)
                assert self.block_mgr.seq_tokens(req.req_id) \
                    == int(self.lengths[i]) + 1

    def _admit_from_pull(self) -> None:
        """Request pulling: admit while capacity allows; a refused request
        is handed back to the virtual-queue owner via take_pushback()."""
        if self.pull_source is None:
            return
        # NOTE: the loop must keep calling pull_source even after a past
        # refusal — taking the pushback back into the queue happens inside
        # the puller (lso._pull), so gating the loop on `_pushback is None`
        # would freeze admission forever after the first refusal
        while self._free_slot() is not None:
            req = self.pull_source()
            if req is None:
                break
            if not self.admit(req):
                # pool-pressure valve: evicted requests' snapshot pins can
                # accumulate until no admission fits (sustained shedding
                # under overload).  Materialize the pinned snapshots —
                # their prefix pages move to host memory and the pins are
                # released — then retry once before pushing back.
                if self._pinned_snapshots:
                    self._materialize_pinned_snapshots()
                    if self.admit(req):
                        continue
                self._pushback = req
                break

    def step(self) -> List[Request]:
        """Admit from the pull source, run one prefill chunk round, then one
        decode iteration.  Returns requests completed this step."""
        self._admit_from_pull()
        # requests that finished inside admit() since the last step are
        # already in self.completed; return them alongside this step's
        done: List[Request] = []
        # one prefill chunk for every mid-prefill slot (batched), then a
        # continuous-batching decode iteration for decode-ready slots
        self._prefill_chunk_round(done)
        self._decode_round(done)
        self.completed.extend(done)
        admit_done, self._admit_completed = self._admit_completed, []
        self._check_invariants()
        return admit_done + done

    def steps(self, k: Optional[int] = None) -> List[Request]:
        """Fast-path iteration: like ``step()`` but the decode side runs up
        to ``k`` iterations (default ``cfg.decode_burst``, which also caps
        the fused buffer width) in ONE jitted dispatch, syncing to host
        once per burst instead of once per token.

        Automatic single-step fallback whenever the fused loop can't run
        soundly at width >= 2: a slot is mid-prefill (the chunk round must
        interleave with decode at token granularity), or the block pool is
        at the preemption edge (the single-step append/preempt path owns
        eviction-LSO ordering).  Pull / evict / swap LSOs act between
        bursts — external evict_request / swap_model calls bump the block
        table version, so the next dispatch sees a fresh device table.
        Token-identical to the ``step()`` loop on every backend."""
        k = self.cfg.decode_burst if k is None else k
        if k <= 1:
            return self.step()
        self._admit_from_pull()
        done: List[Request] = []
        if self.prefilling_slots():
            self._prefill_chunk_round(done)
            self._decode_round(done)
        else:
            self._decode_burst_round(done, k)
        self.completed.extend(done)
        admit_done, self._admit_completed = self._admit_completed, []
        self._check_invariants()
        return admit_done + done

    # ------------------------------------------------------------------
    # runtime invariant checking (repro.analysis.invariants)
    # ------------------------------------------------------------------
    _inv_sampler = None

    def _check_invariants(self) -> None:
        """Round-boundary hook: the per-slot length/allocation contracts
        and the BlockManager state machine are only quiescent here — the
        checker must not run mid-round."""
        if not self.cfg.debug_invariants:
            from repro.analysis.invariants import invariants_enabled
            if not invariants_enabled():
                return
        if self._inv_sampler is None:
            from repro.analysis.invariants import InvariantSampler
            self._inv_sampler = InvariantSampler()
        if self._inv_sampler.due():
            from repro.analysis.invariants import check_engine
            check_engine(self, where=f"engine:{self.model_name}/round")

    # ------------------------------------------------------------------
    # profiling (feeds the RWT estimator + simulator)
    # ------------------------------------------------------------------
    def profile(self, prompts: List[np.ndarray], max_new_tokens: int = 32) -> Dict[str, float]:
        """Run one batch (paper §6 "Hardware Profiling": a single batch run)
        and return {prefill_time P, decode_per_token d, throughput theta}.

        Prefill compute happens inside ``step()`` on the chunked path, so
        the phase split comes from the engine's own stats accounting."""
        import repro.core.request as req_mod
        reqs = [req_mod.Request(prompt_tokens=p, model=self.model_name,
                                slo=1e9, max_new_tokens=max_new_tokens)
                for p in prompts]
        s = self.stats
        pf0, dt0, it0, tok0 = (s.prefill_time, s.decode_time,
                               s.decode_iterations, s.tokens_generated)
        for r in reqs:
            if not self.admit(r):
                break
        n_admitted = self.num_active()
        while self.num_active() > 0:
            # steps() so calibration measures the engine's configured
            # operating mode: burst engines amortize dispatch across the
            # burst, and decode_per_token must reflect that (burst 1 ==
            # the plain step() loop)
            self.steps()
        # the timed regions inside the rounds block_until_ready the step
        # outputs (cache included), so the phase stats below measure real
        # compute, not async dispatch; this final sync is belt-and-braces
        # for any admit-path work still in flight
        jax.block_until_ready(self.cache)
        prefill_t = s.prefill_time - pf0
        decode_t = s.decode_time - dt0
        iters = s.decode_iterations - it0
        tokens = s.tokens_generated - tok0
        return {
            "prefill_time": prefill_t / max(n_admitted, 1),
            "decode_per_token": decode_t / max(iters, 1),
            "throughput": tokens / max(decode_t, 1e-9),
            "batch_size": float(n_admitted),
        }
