"""Async continuous-batching front end: the millions-of-users surface.

``launch/serve.py`` drives the QLM stack as a synchronous polling loop —
no backpressure, no cancellation, no way to shed batch traffic when
interactive SLOs are at risk; exactly the failure mode a queue manager
exists to prevent.  ``AsyncServer`` puts a real queue manager in front of
the engines (blueprint: the Redis LLM-queue architecture — a bounded
request queue decoupling producers from LLM workers, with depth
visibility and backpressure to the ingest layer):

  * **bounded request queue** — queue depth is the number of admitted
    requests that have not yet produced a first token; ``submit()``
    rejects 429-style at hard capacity (``FrontendConfig.queue_depth``),
    and a high/low **watermark** pair gives hysteresis backpressure: once
    depth crosses ``high_watermark`` the server sheds batch-class
    arrivals at the door until depth falls back under ``low_watermark``
    (interactive traffic keeps flowing until the hard cap);
  * **admission control** — optional ``core.autoscale.AdmissionController``
    gate: reject when the RWT-estimated queue drain already exceeds the
    request's own TTFT SLO (``admission="slo"``) or a fixed bound
    (``admission=<seconds>``) — §9 option (c), rate limiting so admitted
    requests can still meet SLOs;
  * **per-request deadlines** — a request whose deadline passes before
    any dispatch is EXPIRED: it never reaches an engine, its group cursor
    skips it, and attainment accounting counts it as a miss (not a
    silent omission);
  * **cancellation** — ``RequestStream.cancel()`` propagates into the
    engine mid-decode/mid-prefill via ``engine.cancel_request``: the slot
    is freed and its KV pages are back on the free list at the next sweep
    (contract documented in ``serving/engine.py``);
  * **token streaming** — ``submit()`` returns a ``RequestStream`` async
    iterator; tokens are pumped from the engine's per-request output
    after every iteration, so a client consumes them while the request
    is still decoding;
  * **graceful shedding** — when ``GlobalScheduler.violations`` predicts
    an *interactive* deadline violation (``slo_ceiling`` filter), the
    server defers batch-class groups behind interactive ones in the hot
    instance's virtual queue and evicts (``shed_policy="defer"``) or
    cancels (``"drop"``) the running batch-class slots, freeing capacity
    for the traffic that is actually at risk.

The event loop owns the engines: one cooperative task interleaves
sweeping (cancellation + deadline expiry), arrival pumping, shedding, one
``QLMAgent.run_iteration()`` per instance, and token pumping, yielding to
client coroutines between iterations.  JAX dispatch is synchronous on
CPU, so an iteration blocks the loop for its compute — the awaits between
iterations are where submissions, cancellations and stream consumption
interleave.

Multi-turn sessions (``data.workload.Session``) ride this surface: a
follow-up request re-enters the queue carrying the previous turns' tokens
as a prompt prefix, so the prefix index and ``fork_slot`` serve real
session traffic (drive them with ``run_session``).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

from repro.core.autoscale import AdmissionController
from repro.core.qlm import DEAD, QLMController
from repro.core.request import SLO_INTERACTIVE, Request
from repro.core.rwt_estimator import WorkloadProfile
from repro.serving.faults import EngineFailure

if TYPE_CHECKING:  # lso imports serving.engine — avoid the import cycle
    from repro.core.lso import QLMAgent

_DONE = object()          # stream sentinel: normal termination
SHED_POLICIES = ("off", "defer", "drop")


@dataclasses.dataclass
class FrontendConfig:
    # Hard bound on queued-unstarted requests: submissions past this are
    # rejected 429-style regardless of class.
    queue_depth: int = 64
    # Backpressure hysteresis (absolute request counts; None derives 3/4
    # and 1/2 of queue_depth).  Engaged at >= high, released at <= low;
    # while engaged, batch-class arrivals are rejected at the door.
    high_watermark: Optional[int] = None
    low_watermark: Optional[int] = None
    # Overload shedding when an INTERACTIVE violation is predicted:
    # "defer" evicts running batch-class slots (resumable) and pushes
    # batch groups behind interactive ones; "drop" cancels them outright;
    # "off" disables.
    shed_policy: str = "defer"
    shed_cooldown_s: float = 0.25
    # Groups with slo <= this are "interactive" for shedding/backpressure
    # class decisions (the paper's 20 s class by default).
    interactive_slo_ceiling: float = SLO_INTERACTIVE
    # RWT admission gate: None = off; "slo" bounds estimated drain by each
    # request's own TTFT SLO; a float is a fixed drain bound in seconds.
    admission: Optional[object] = None
    # Event-loop pacing: sleep this long when no engine has active slots
    # (0 -> bare yield).
    idle_sleep_s: float = 0.002
    # Periodic controller.tick() interval (violation-triggered reschedule
    # off the submit path).
    tick_interval_s: float = 0.25

    def resolved_watermarks(self) -> Tuple[int, int]:
        high = self.high_watermark
        low = self.low_watermark
        if high is None:
            high = max(1, (3 * self.queue_depth) // 4)
        if low is None:
            low = max(0, self.queue_depth // 2)
        return high, min(low, high)


@dataclasses.dataclass
class FrontendStats:
    submitted: int = 0
    accepted: int = 0
    rejected_full: int = 0           # hard queue_depth cap
    rejected_backpressure: int = 0   # watermark shed of batch arrivals
    rejected_admission: int = 0      # RWT drain gate
    rejected_deadline: int = 0       # dead on arrival (deadline already past)
    rejected_unservable: int = 0     # 400-style: no alive instance serves it
    rejected_capacity: int = 0       # 503-style: capacity-scaled queue bound
    engine_failures: int = 0         # agent iterations that raised
    expired: int = 0                 # deadline passed while queued
    cancelled: int = 0               # client cancellations executed
    shed_deferred: int = 0           # running slots evicted by the shedder
    shed_dropped: int = 0            # running slots cancelled by the shedder
    deferred_groups: int = 0         # batch groups pushed behind interactive
    tokens_streamed: int = 0
    backpressure_engagements: int = 0
    max_queue_depth: int = 0
    iterations: int = 0

    @property
    def rejected(self) -> int:
        return (self.rejected_full + self.rejected_backpressure
                + self.rejected_admission + self.rejected_deadline
                + self.rejected_unservable + self.rejected_capacity)

    # Every rate below guards its denominator: a zero-request run (or a
    # run where everything was rejected) must report clean numbers, not
    # raise ZeroDivisionError mid-shutdown or leak NaN into JSON stats.
    @property
    def acceptance_rate(self) -> float:
        """accepted / submitted; vacuously 1.0 when nothing arrived."""
        return self.accepted / self.submitted if self.submitted else 1.0

    @property
    def rejection_rate(self) -> float:
        return self.rejected / self.submitted if self.submitted else 0.0

    @property
    def expiry_rate(self) -> float:
        """Queue-expired fraction of what was actually accepted."""
        return self.expired / self.accepted if self.accepted else 0.0

    @property
    def mean_tokens_per_accepted(self) -> float:
        return (self.tokens_streamed / self.accepted
                if self.accepted else 0.0)


class RequestStream:
    """Per-request async token iterator — the client's handle.

    ``async for tok in stream`` yields tokens as the engine produces them
    and terminates when the request finishes, is cancelled, expires, or
    was rejected.  ``status`` distinguishes the outcomes.
    """

    def __init__(self, req: Request, server: "AsyncServer"):
        self.request = req
        self._server = server
        self._queue: asyncio.Queue = asyncio.Queue()
        self._delivered = 0
        self._finished = False
        self._exc: Optional[BaseException] = None

    def __aiter__(self) -> "RequestStream":
        return self

    async def __anext__(self) -> int:
        if self._finished and self._queue.empty():
            if self._exc is not None:
                raise self._exc
            raise StopAsyncIteration
        tok = await self._queue.get()
        if tok is _DONE:
            self._finished = True
            if self._exc is not None:
                raise self._exc
            raise StopAsyncIteration
        return tok

    def cancel(self) -> None:
        """Request cancellation: the server sweep executes it before the
        next engine iteration (slot + KV pages freed mid-decode)."""
        self.request.cancel_requested = True

    async def drain(self) -> List[int]:
        """Consume the remainder of the stream and return all its tokens."""
        async for _ in self:
            pass
        return list(self.request.output_tokens)

    @property
    def status(self) -> str:
        r = self.request
        if r.rejected:
            return "rejected"
        if r.failed:
            return "failed"       # quarantined after engine death(s)
        if r.expired:
            return "expired"
        if r.shed:
            return "shed"
        if r.cancelled:
            return "cancelled"
        if r.finished():
            return "completed"
        return "queued" if r.first_token_time is None else "running"

    # server-side plumbing -------------------------------------------------
    def _push(self, tok: int) -> None:
        self._queue.put_nowait(tok)

    def _abort(self, exc: BaseException) -> None:
        """Serve-loop crash: fail this stream's consumers with the crash
        instead of leaving them awaiting tokens that will never come."""
        self._exc = exc
        self._queue.put_nowait(_DONE)

    def _close(self) -> None:
        self._queue.put_nowait(_DONE)


class AsyncServer:
    """Event-loop front end over a ``QLMController`` + one ``QLMAgent``
    per instance (``controller.instances`` order must match ``agents``)."""

    def __init__(self, controller: QLMController, agents: List[QLMAgent],
                 cfg: Optional[FrontendConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        if cfg is not None and cfg.shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                             f"got {cfg.shed_policy!r}")
        assert len(agents) == len(controller.instances), \
            (len(agents), len(controller.instances))
        self.controller = controller
        self.agents = list(agents)
        self.cfg = cfg or FrontendConfig()
        self.clock = clock
        self.stats = FrontendStats()
        self._live: Dict[int, RequestStream] = {}   # req_id -> stream
        self._backpressure = False
        self._stopping = False
        self._task: Optional[asyncio.Task] = None
        self._last_shed = -1e18
        self._last_tick = -1e18
        self._admission: Dict[tuple, AdmissionController] = {}
        # supervision: the controller reclaims a dead engine's resident
        # requests (mark_dead -> abandon) and the terminal-state invariant
        # cross-checks engine residency
        controller.attach_engines(self.engines)

    # -- context manager ---------------------------------------------------
    async def __aenter__(self) -> "AsyncServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    @property
    def engines(self):
        return [a.engine for a in self.agents]

    # -- ingress -----------------------------------------------------------
    def queue_depth(self) -> int:
        """Admitted requests with no first token yet (the bounded queue)."""
        return sum(1 for s in self._live.values()
                   if s.request.first_token_time is None
                   and not s.request.finished())

    def _is_interactive(self, req: Request) -> bool:
        return req.slo <= self.cfg.interactive_slo_ceiling

    def _scaled_limits(self) -> Tuple[int, int, int]:
        """(hard cap, high, low) scaled by the serving-capacity fraction:
        when engines die OR drain (departing capacity counts as gone for
        NEW work), the queue the survivors can absorb in the same time
        shrinks proportionally, so the watermarks tighten and excess
        arrivals shed 503-style instead of stranding past their SLOs.
        Zero serving capacity (all dead/draining, or no instances
        attached at all) pins the cap to 0: everything rejects
        503-style, nothing throws."""
        high, low = self.cfg.resolved_watermarks()
        frac = getattr(self.controller, "serving_fraction",
                       self.controller.alive_fraction)()
        if frac >= 1.0:
            return self.cfg.queue_depth, high, low
        if frac <= 0.0:
            return 0, 0, 0
        cap = max(1, int(self.cfg.queue_depth * frac))
        return cap, max(1, int(high * frac)), int(low * frac)

    def _update_backpressure(self, depth: int) -> None:
        _, high, low = self._scaled_limits()
        if not self._backpressure and depth >= high:
            self._backpressure = True
            self.stats.backpressure_engagements += 1
        elif self._backpressure and depth <= low:
            self._backpressure = False

    def _admission_gate(self, req: Request, depth: int) -> bool:
        """True = admit.  Lazily builds one AdmissionController per
        (model, bound, serving-set) — the §9(c) drain check against the
        best CALIBRATED profile among the SCHEDULABLE instances that can
        serve this model, with the cluster-wide queue depth split across
        them.  Keying on the serving-set identity rebuilds the gate when
        instances die, drain, or get replaced (a cached controller built
        from a dead instance's profile would mis-bound forever)."""
        if self.cfg.admission is None:
            return True
        bound = req.slo if self.cfg.admission == "slo" \
            else float(self.cfg.admission)  # type: ignore[arg-type]
        serving = tuple(
            i.instance_id
            for idx, i in enumerate(self.controller.instances)
            if self.controller.is_schedulable(idx)
            and req.model in i.hw_by_model)
        if not serving:
            # can_serve() gated above; a race that empties the set
            # between the two checks falls through to the queue bound
            return True
        # replacements reuse the slot id but may carry a new profile:
        # the counter in the key forces a rebuild after every replace
        key = (req.model, bound, serving,
               getattr(self.controller, "replacements", 0))
        ac = self._admission.get(key)
        if ac is None:
            by_id = {i.instance_id: i for i in self.controller.instances}
            hws = [by_id[sid].hw(req.model) for sid in serving]
            hw = max(hws, key=lambda h: h.throughput(
                WorkloadProfile(req.prompt_len, 1.0,
                                float(req.max_new_tokens), 1.0)))
            ac = AdmissionController(self.controller.estimator, hw, bound,
                                     n_instances=len(serving))
            self._admission[key] = ac
        return ac.admit(req, depth)

    def _reject(self, req: Request, now: float, counter: str) -> RequestStream:
        self.controller.record_rejection(req, now)
        setattr(self.stats, counter, getattr(self.stats, counter) + 1)
        stream = RequestStream(req, self)
        stream._close()
        return stream

    async def submit(self, req: Request) -> RequestStream:
        """Gateway entry.  Always returns a stream; a rejected request's
        stream terminates immediately with ``status == "rejected"``
        (429-style — the paper's admission-control option, not an
        exception, so callers can account it)."""
        now = self.clock()
        self.stats.submitted += 1
        if self._task is not None and self._task.done():
            # fail fast instead of queueing onto a dead loop
            self._task.result()  # re-raises the serve loop's crash
        if self._stopping:
            return self._reject(req, now, "rejected_full")
        # 400-style: a model no ALIVE instance serves gets a recorded
        # rejection (an attainment miss), not an exception — one bad
        # request or a dead engine pool must not kill the serve loop
        if not self.controller.can_serve(req.model):
            return self._reject(req, now, "rejected_unservable")
        if now > req.deadline:
            req.expired = True
            return self._reject(req, now, "rejected_deadline")
        depth = self.queue_depth()
        self.stats.max_queue_depth = max(self.stats.max_queue_depth, depth)
        cap, _, _ = self._scaled_limits()
        self._update_backpressure(depth)
        if depth >= cap:
            # 503-style when the bound shrank with lost capacity,
            # 429-style at the configured hard cap
            return self._reject(req, now, "rejected_capacity"
                                if cap < self.cfg.queue_depth
                                else "rejected_full")
        if self._backpressure and not self._is_interactive(req):
            return self._reject(req, now, "rejected_backpressure")
        if not self._admission_gate(req, depth):
            return self._reject(req, now, "rejected_admission")
        self.controller.submit(req, now)
        self.stats.accepted += 1
        stream = RequestStream(req, self)
        self._live[req.req_id] = stream
        return stream

    # -- lifecycle sweeps (run on the loop task, never mid-dispatch) -------
    def _terminate(self, req: Request, now: float) -> None:
        """Free any engine-side state (slot / snapshot) for a request that
        will never run again, then stamp it finished so group cursors
        skip it.  Dead engines are skipped: their state was reclaimed by
        ``mark_dead`` and there is nothing left to cancel."""
        for idx, eng in enumerate(self.engines):
            if self.controller.is_alive(idx) and eng.cancel_request(req):
                break
        req._in_flight = False
        req._served_by = None
        if req.completion_time is None:
            req.completion_time = now

    def _sweep(self, now: float) -> None:
        for stream in list(self._live.values()):
            req = stream.request
            if req.finished():
                continue
            if req.cancel_requested:
                self._terminate(req, now)
                req.cancelled = True
                self.stats.cancelled += 1
            elif req.first_token_time is None and now > req.deadline:
                # deadline-expired while queued: never dispatch it — the
                # capacity goes to requests that can still meet their SLO
                self._terminate(req, now)
                req.expired = True
                self.stats.expired += 1

    def _maybe_shed(self, now: float) -> None:
        cfg = self.cfg
        if cfg.shed_policy == "off" \
                or now - self._last_shed < cfg.shed_cooldown_s:
            return
        # the cooldown paces the CHECK, not just the shed: the violations
        # walk is O(groups) of estimator math, far too hot for every
        # engine iteration
        self._last_shed = now
        # alive (instance, agent) pairs: a dead engine has no slots to
        # shed, and misaligning infos with agents would read the wrong
        # engine's inflight drain
        pairs = [(inst, agent) for idx, (inst, agent)
                 in enumerate(zip(self.controller.instances, self.agents))
                 if self.controller.is_alive(idx)]
        infos = [inst for inst, _ in pairs]
        hot = self.controller.scheduler.violations(
            infos, now, slo_ceiling=cfg.interactive_slo_ceiling,
            inflight=self._inflight_drain(pairs))
        ceiling = cfg.interactive_slo_ceiling
        for inst in infos:
            vq = inst.virtual_queue
            inter = [g for g in vq.groups
                     if not g.done() and g.slo <= ceiling]
            if not inter:
                continue
            batch = [g for g in vq.groups
                     if not g.done() and g.slo > ceiling]
            # defer: interactive groups drain first, batch groups keep
            # their relative order behind them.  Ordering alone waits for
            # no violation — reacting only once a deadline is PREDICTED
            # to blow leaves every queued interactive request one queue
            # drain short of its SLO (new arrivals land at the VQ tail,
            # behind previously deferred batch work)
            if batch and self._batch_ahead(vq.groups, ceiling):
                vq.set_order(inter + batch)
                self.stats.deferred_groups += len(batch)
            # eviction is the expensive lever: only when this instance's
            # walk actually predicts an interactive violation
            if inst not in hot:
                continue
            eng = self._engine_for(inst)
            if eng is None:
                continue
            drop = cfg.shed_policy == "drop"
            shed = eng.shed_slots(
                lambda r: r.slo > ceiling, drop=drop)
            if drop:
                self.stats.shed_dropped += len(shed)
            else:
                self.stats.shed_deferred += len(shed)

    @staticmethod
    def _batch_ahead(groups, ceiling: float) -> bool:
        """True if some undone batch group precedes an undone interactive
        group (i.e. the defer reorder would change anything)."""
        seen_batch = False
        for g in groups:
            if g.done():
                continue
            if g.slo > ceiling:
                seen_batch = True
            elif seen_batch:
                return True
        return False

    def _inflight_drain(self, pairs) -> List[float]:
        """Seconds until each instance's engine can free a slot — the VQ
        walk's seed.  0 when a slot is already free; otherwise the fastest
        running request's remaining decode (a queued request cannot start
        sooner than that).  Takes (instance, agent) PAIRS so a filtered
        alive subset stays aligned with its engines."""
        out = []
        for inst, agent in pairs:
            eng = agent.engine
            running = eng.running_requests()
            hw = inst.hw_by_model.get(eng.model_name)
            if hw is None or len(running) < eng.cfg.max_slots:
                out.append(0.0)
                continue
            steps = min(max(0, r.max_new_tokens - len(r.output_tokens))
                        for r in running)
            out.append(steps * hw.decode_per_token * hw.inefficiency)
        return out

    def _engine_for(self, inst):
        for i, agent in zip(self.controller.instances, self.agents):
            if i is inst:
                return agent.engine
        return None

    def _pump_tokens(self) -> None:
        for req_id, stream in list(self._live.items()):
            req = stream.request
            toks = req.output_tokens
            while stream._delivered < len(toks):
                stream._push(int(toks[stream._delivered]))
                stream._delivered += 1
                self.stats.tokens_streamed += 1
            if req.finished():
                stream._close()
                del self._live[req_id]

    # -- the event loop ----------------------------------------------------
    async def start(self) -> None:
        assert self._task is None, "server already started"
        self._stopping = False
        self._task = asyncio.ensure_future(self._run())

    async def _run(self) -> None:
        # A serve-loop crash (engine error, invariant violation, bug) must
        # FAIL every waiting client promptly: the task dying silently
        # would leave each `await stream.drain()` / `server.drain()`
        # hanging on tokens that will never arrive.
        try:
            await self._run_inner()
        except BaseException as e:
            for stream in list(self._live.values()):
                stream._abort(e)
            raise

    async def _run_inner(self) -> None:
        cfg = self.cfg
        while True:
            now = self.clock()
            self._sweep(now)
            self._maybe_shed(now)
            if now - self._last_tick >= cfg.tick_interval_s:
                self._last_tick = now
                self.controller.tick(now)
            busy = False
            for idx, (inst, agent) in enumerate(
                    zip(self.controller.instances, self.agents)):
                if not self.controller.is_alive(idx):
                    continue
                inst.current_model = agent.engine.model_name
                try:
                    # qlint: disable=blocking-in-async -- the loop owns the engines: cancel/evict/shed paths run between awaits and must never overlap an engine round, so the round runs inline (single host thread; offloading would race them)
                    agent.run_iteration()
                except EngineFailure as e:
                    # supervision: crashes kill the instance (its requests
                    # are redelivered from the global queue), transient
                    # errors strike it.  Anything else — a real bug, an
                    # InvariantViolation — still propagates and aborts
                    # every stream (fail loudly, not around).
                    self.stats.engine_failures += 1
                    if self.controller.report_engine_failure(
                            idx, e, now, engine=agent.engine) == DEAD:
                        agent.reset()
                    continue
                self.controller.heartbeat(idx, now)
                busy |= agent.engine.num_active() > 0
            self._pump_tokens()
            self.stats.iterations += 1
            if self._stopping and not self._live:
                break
            # an un-finished live stream means queued or running work; an
            # O(groups×requests) VQ walk here would rival the decode step
            busy |= bool(self._live)
            # the await is the scheduling point: submissions, cancellations
            # and stream consumers interleave here
            await asyncio.sleep(0.0 if busy else cfg.idle_sleep_s)

    async def drain(self) -> None:
        """Wait until every accepted request reached a terminal state."""
        while self._live:
            if self._task is not None and self._task.done():
                self._task.result()  # re-raises the serve loop's crash
                raise RuntimeError(
                    f"serve loop exited with {len(self._live)} live "
                    f"request(s)")
            await asyncio.sleep(0.001)

    async def stop(self, cancel_outstanding: bool = False) -> None:
        """Graceful shutdown: stop accepting, optionally cancel what's
        still in flight (otherwise wait for it to drain), stop the loop.
        Either way no KV block stays allocated to a dead request: cancel
        frees slots/snapshots, drain lets them finish."""
        self._stopping = True
        if cancel_outstanding:
            for stream in list(self._live.values()):
                stream.cancel()
        if self._task is not None:
            await self._task
            self._task = None


async def run_session(server: AsyncServer, session, *,
                      clock: Callable[[], float] = time.monotonic) -> list:
    """Drive a multi-turn ``data.workload.Session``: submit each turn,
    stream it to completion, fold prompt+output into the session history
    (the next turn's prompt prefix — PR 5's prefix index serves it from
    cache), think, repeat.  Returns the session's request list."""
    while True:
        req = session.next_request(clock())
        if req is None:
            return session.requests
        stream = await server.submit(req)
        await stream.drain()
        if stream.status != "completed":
            return session.requests  # rejected / expired / cancelled turn
        session.complete_turn(req)
        if session.think_time_s > 0:
            await asyncio.sleep(session.think_time_s)
