"""Paged KV-cache block manager (PagedAttention-style accounting) with
refcounted prefix sharing and copy-on-write pages.

This manager owns the **allocation state machine** the iteration scheduler
uses for admission / preemption decisions: a free list of fixed-size
blocks, a per-sequence block table, and token-capacity queries.  The
paper's RWT estimator consumes ``GPU`` (total token capacity) from here
(Appendix A.1, Eq. 16).

Under the dense attention backends the block ids are pure bookkeeping (the
KV lives in per-slot ``(B, KVH, S, D)`` arrays); under the paged backends
(``attention_backend="paged-*"``) each id names a PHYSICAL page of the
global pool ``(num_blocks, KVH, block_size, D)`` — freeing a sequence
makes its HBM immediately reusable by any other sequence.

KV-page lifecycle (allocate -> share -> COW -> evict/snapshot -> resume)
-----------------------------------------------------------------------
Every physical block carries a **refcount**:

  * ``allocate`` pops blocks off the free list at refcount 1 (sole owner).
  * Once a block is FULL and its token contents are known, the engine
    publishes it to the **prefix index** (``register_prefix``): a
    ``(parent_physical_block, token_tuple) -> block_id`` map.  Chains are
    content-addressed by walking the map from the root (parent ``-1``), so
    two prompts sharing a leading template resolve to the SAME physical
    chain without hashing whole prefixes (vLLM-style chained block hash,
    but exact — keyed on the parent's physical id + raw token ids, so hash
    collisions cannot alias different contents).
  * ``match_prefix`` walks the index over an incoming prompt and returns
    the longest indexed chain covering at most ``len(prompt) - 1`` tokens
    (at least one prompt token must still run prefill to produce the
    first-token logits); ``share_prefix`` then attaches a new sequence to
    that chain — refcount + 1 per shared block, zero page copies — and
    allocates fresh blocks only for the private tail.  ``fork`` clones a
    whole live sequence the same way (parallel-sampling style).
  * **Copy-on-write**: any write that would land in a block with
    refcount > 1 (``append_token`` / ``extend`` growing into a shared
    partial tail block, or ``fork`` of a sequence whose last block is
    partial) first moves the writer onto a fresh private copy.  The
    manager only re-points the table (old refcount - 1, new block at
    refcount 1) and records ``(src, dst)`` in a pending op list; the
    engine drains ``take_cow_ops`` and performs the actual page copy on
    device before the next dispatch.  Shared blocks in the index are
    always full and never written, so COW sources are never indexed.
  * **Eviction** (``evict_split``): leading blocks still referenced by
    another owner (refcount > 1) are NOT freed or copied — the departing
    sequence's reference transfers to a **pin** held by its host-side
    snapshot, so the chain outlives even the other sharers.  Only the
    private tail is released (and its page contents snapshotted by the
    engine).  ``resume_pinned`` hands the pinned chain back to the
    resuming sequence (pin -> sequence reference, still no copies);
    ``release_pins`` drops a snapshot that will never resume.  Pins are
    epoch-guarded: ``reset`` invalidates every outstanding pin.
  * A block whose refcount reaches 0 is deregistered from the prefix
    index and returned to the free list — it can never be reached through
    a stale chain afterwards (the index only ever names live blocks).
  * **Freed-block cache** (``cache_freed=True``, off by default): an
    indexed block whose refcount reaches 0 stays in the prefix index on a
    free-but-cached LRU list instead of being deregistered, so a LATER
    request with the same leading tokens (a multi-turn session's follow-up
    carrying the previous turn as its prompt prefix) still matches after
    the original sequence finished.  Cached blocks count as free capacity:
    allocation evicts the LRU cached subtree on demand (descendants of a
    cached block are themselves cached — refcounts are non-increasing
    along a chain — and are dropped with it so a reused physical id can
    never alias stale content), and ``share_prefix`` revives matched
    cached blocks back to refcount 1 with zero copies.

The manager can additionally maintain an **incremental slot table**
(``attach_slot_table``): a persistent fixed-shape ``(rows, width)`` int32
array mapping engine slots to physical page ids, updated in place by every
allocate/share/fork/COW/extend/append_token/free instead of being rebuilt
O(rows x width) in Python each engine iteration.  ``table_version`` bumps
on every table mutation so the engine refreshes its device copy only when
something actually changed.  Two rows may name the same physical page
(shared prefixes); the kernels only ever read shared pages — writes target
private blocks, which COW guarantees.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# prefix-index key: (parent physical block id | -1 for the root,
#                    token ids filling this block)
PrefixKey = Tuple[int, Tuple[int, ...]]


class OutOfBlocksError(RuntimeError):
    pass


@dataclasses.dataclass
class SeqAlloc:
    block_table: List[int]
    num_tokens: int
    # leading full blocks already published to the prefix index (a lazy
    # watermark — register_prefix is idempotent and re-walks are cheap)
    registered: int = 0


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 watermark: float = 0.01, cache_freed: bool = False):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.cache_freed = cache_freed
        # reserve a small watermark so decode appends don't immediately OOM
        self.watermark_blocks = max(1, int(num_blocks * watermark))
        self._free: List[int] = list(range(num_blocks))
        # freed-but-indexed blocks (cache_freed): LRU insertion order,
        # evicted on demand by _acquire, revived by share_prefix
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self._seqs: Dict[int, SeqAlloc] = {}
        # per-block reference counts: 0 = free, 1 = sole owner, >1 = shared
        self._ref = np.zeros(num_blocks, np.int32)
        # snapshot pins: block -> number of evicted-sequence snapshots
        # holding a reference (each pin is one unit of _ref)
        self._pins: Dict[int, int] = {}
        # prefix index: chained content-addressed full blocks
        self._index: Dict[PrefixKey, int] = {}
        self._block_key: Dict[int, PrefixKey] = {}
        # pending (src, dst) page copies the engine must apply on device
        # before its next dispatch
        self._cow_ops: List[Tuple[int, int]] = []
        # bumped by reset(): outstanding pins from before a reset are dead
        self.epoch = 0
        # incremental slot table (attach_slot_table): row per engine slot,
        # sentinel num_blocks for unallocated logical blocks / unbound rows
        self._table: Optional[np.ndarray] = None
        self._seq_rows: Dict[int, int] = {}
        self.table_version = 0

    # ------------------------------------------------------------------
    # incremental slot table
    # ------------------------------------------------------------------
    def attach_slot_table(self, rows: int, width: int) -> None:
        """Maintain a persistent ``(rows, width)`` int32 slot -> physical
        page table.  Row ``r`` mirrors the block table of the sequence bound
        to it via ``bind_slot``; unbound rows and unallocated logical blocks
        hold the sentinel ``num_blocks`` (writes dropped, reads masked).
        Every subsequent allocate/extend/append_token/free updates the table
        in place — O(new blocks) instead of an O(rows x width) rebuild."""
        self._table = np.full((rows, width), self.num_blocks, np.int32)
        self._seq_rows.clear()
        self.table_version += 1

    def bind_slot(self, seq_id: int, row: int) -> None:
        """Bind an allocated sequence to a table row (engine slot) and
        populate the row from its current block table."""
        if self._table is None:
            return
        assert seq_id in self._seqs, seq_id
        self._seq_rows[seq_id] = row
        blocks = self._seqs[seq_id].block_table
        assert len(blocks) <= self._table.shape[1], \
            (len(blocks), self._table.shape)
        self._table[row, :len(blocks)] = blocks
        self._table[row, len(blocks):] = self.num_blocks
        self.table_version += 1

    def _table_append(self, seq_id: int, new_blocks: List[int],
                      start: int) -> None:
        """Record blocks just appended to ``seq_id``'s block table at
        logical positions [start, start + len(new_blocks))."""
        if self._table is None or not new_blocks:
            return
        row = self._seq_rows.get(seq_id)
        if row is None:
            return
        assert start + len(new_blocks) <= self._table.shape[1], \
            (start, len(new_blocks), self._table.shape)
        self._table[row, start:start + len(new_blocks)] = new_blocks
        self.table_version += 1

    def _table_set(self, seq_id: int, idx: int, block: int) -> None:
        """Re-point one logical position (COW re-targeting)."""
        if self._table is None:
            return
        row = self._seq_rows.get(seq_id)
        if row is None:
            return
        self._table[row, idx] = block
        self.table_version += 1

    def _table_clear(self, seq_id: int) -> None:
        row = self._seq_rows.pop(seq_id, None)
        if self._table is not None and row is not None:
            self._table[row, :] = self.num_blocks
            self.table_version += 1

    def slot_table(self) -> Optional[np.ndarray]:
        """The incrementally-maintained table (None until attached).  The
        caller must treat it as read-only; it is mutated in place by the
        allocation state machine."""
        return self._table

    # ------------------------------------------------------------------
    @property
    def token_capacity(self) -> int:
        return self.num_blocks * self.block_size

    @property
    def free_blocks(self) -> int:
        """Allocatable blocks: the free list plus the freed-but-cached
        blocks (evictable on demand, so they ARE capacity)."""
        return len(self._free) + len(self._cached)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    def tokens_allocated(self) -> int:
        return sum(s.num_tokens for s in self._seqs.values())

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def ref_count(self, block: int) -> int:
        return int(self._ref[block])

    def pin_count(self, block: int) -> int:
        return self._pins.get(block, 0)

    def can_allocate(self, num_tokens: int, *, respect_watermark: bool = True,
                     reserve_blocks: int = 0, shared_blocks: int = 0) -> bool:
        """``reserve_blocks``: extra blocks already promised elsewhere (e.g.
        the unallocated remainder of mid-prefill sequences).
        ``shared_blocks``: leading blocks that will be attached from the
        prefix index (or a pinned snapshot) instead of the free list."""
        need = max(self.blocks_needed(num_tokens) - shared_blocks, 0)
        reserve = self.watermark_blocks if respect_watermark else 0
        return need <= self.free_blocks - reserve - reserve_blocks

    # ------------------------------------------------------------------
    # block acquisition / release
    # ------------------------------------------------------------------
    def _acquire(self, n: int) -> List[int]:
        blocks = []
        for _ in range(n):
            b = self._free.pop() if self._free else self._evict_cached()
            assert self._ref[b] == 0, (b, self._ref[b])
            self._ref[b] = 1
            blocks.append(b)
        return blocks

    def _evict_cached(self) -> int:
        """Reclaim the LRU freed-but-cached block for allocation."""
        block, _ = self._cached.popitem(last=False)
        self._deregister(block)
        return block

    def _deregister(self, block: int) -> None:
        """Remove ``block`` from the prefix index, and with it every
        indexed DESCENDANT: their keys chain through this physical id,
        which is about to become reusable — a reused id must never alias
        stale content.  Descendants of a cached block are cached too
        (refcounts are non-increasing along a chain), so the subtree walk
        moves them from the cache to the plain free list."""
        key = self._block_key.pop(block, None)
        if key is None or self._index.get(key) != block:
            return
        del self._index[key]
        children = [b for (parent, _toks), b in self._index.items()
                    if parent == block]
        for c in children:
            if c in self._cached:
                del self._cached[c]
                self._free.append(c)
            self._deregister(c)

    def _release_block(self, block: int) -> None:
        """Drop one reference; at zero the block is deregistered from the
        prefix index and returned to the free list — or, with
        ``cache_freed``, kept indexed on the cached LRU list so later
        same-prefix admissions still match it."""
        assert self._ref[block] >= 1, block
        self._ref[block] -= 1
        if self._ref[block] == 0:
            if self._cow_ops:
                # A pending COW op whose *destination* dies here is moot (the
                # queuing sequence is gone) and must not run: the block goes
                # back on the free list and may be reallocated before the
                # engine drains take_cow_ops(), so a late copy would clobber
                # the new owner's page.  Ops whose *source* dies stay queued —
                # the old page contents remain valid until the next dispatch,
                # and the engine drains COW ops before dispatching.
                self._cow_ops = [(s, d) for (s, d) in self._cow_ops
                                 if d != block]
            if self.cache_freed \
                    and self._index.get(self._block_key.get(block)) == block:
                self._cached[block] = None
                return
            self._deregister(block)
            self._free.append(block)

    # ------------------------------------------------------------------
    # allocation state machine
    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, num_tokens: int, *,
                 respect_watermark: bool = True) -> List[int]:
        """Allocate a fresh sequence's blocks.

        ``respect_watermark`` defaults to True so an admission-time
        ``can_allocate`` check and the allocation it green-lights enforce
        the SAME bound — previously ``allocate`` ignored the watermark and
        could silently eat the reserve ``can_allocate`` had just refused to
        touch.  Pass False only for allocations that are allowed to dip
        into the reserve (mirroring ``extend`` / ``append_token``, which
        never apply it to in-flight sequences).
        """
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.blocks_needed(num_tokens)
        reserve = self.watermark_blocks if respect_watermark else 0
        if need > self.free_blocks - reserve:
            raise OutOfBlocksError(
                f"need {need} blocks, {self.free_blocks} free"
                + (f" ({reserve} reserved by watermark)" if reserve else ""))
        blocks = self._acquire(need)
        self._seqs[seq_id] = SeqAlloc(block_table=blocks, num_tokens=num_tokens)
        return blocks

    def _cow(self, seq_id: int, idx: int) -> None:
        """Move ``seq_id`` off the shared block at logical position ``idx``
        onto a fresh private copy.  The caller guarantees a free block.
        Only ever hits partial tail blocks — indexed blocks are full and
        never written, so a COW source is never in the prefix index."""
        alloc = self._seqs[seq_id]
        old = alloc.block_table[idx]
        assert self._ref[old] > 1, (old, int(self._ref[old]))
        assert old not in self._block_key, old
        new = self._acquire(1)[0]
        self._ref[old] -= 1
        alloc.block_table[idx] = new
        self._cow_ops.append((old, new))
        self._table_set(seq_id, idx, new)

    def take_cow_ops(self) -> List[Tuple[int, int]]:
        """Drain pending ``(src, dst)`` page copies.  The engine MUST apply
        them on device before the next dispatch that could write ``dst``."""
        ops, self._cow_ops = self._cow_ops, []
        return ops

    def _write_needs_cow(self, alloc: SeqAlloc) -> bool:
        """True when the next appended token lands in an existing block the
        sequence does not own exclusively."""
        if alloc.num_tokens % self.block_size == 0 or not alloc.block_table:
            return False
        return bool(self._ref[alloc.block_table[-1]] > 1)

    def append_needs_cow(self, seq_id: int) -> bool:
        """Engine burst planning: will growing this sequence trigger a COW
        (one extra free block beyond the plain block math)?"""
        return self._write_needs_cow(self._seqs[seq_id])

    def extend(self, seq_id: int, num_tokens: int) -> bool:
        """Grow ``seq_id``'s allocation to cover ``num_tokens`` total.

        Chunk-granular prefill allocates one chunk at a time instead of the
        whole prompt up front; each subsequent chunk extends the allocation.
        Returns False when the needed blocks aren't free (caller preempts) —
        like ``append_token``, the watermark is not applied to in-flight
        sequences.  Growth that writes into a shared partial tail block
        copy-on-writes it first (one extra free block).
        """
        alloc = self._seqs[seq_id]
        if num_tokens <= alloc.num_tokens:
            return True
        need = self.blocks_needed(num_tokens) - len(alloc.block_table)
        cow = self._write_needs_cow(alloc)
        if need + (1 if cow else 0) > self.free_blocks:
            return False
        if cow:
            self._cow(seq_id, len(alloc.block_table) - 1)
        start = len(alloc.block_table)
        for _ in range(need):
            alloc.block_table.append(self._acquire(1)[0])
        alloc.num_tokens = num_tokens
        self._table_append(seq_id, alloc.block_table[start:], start)
        return True

    def append_token(self, seq_id: int) -> bool:
        """Account one more token; returns False if a new block (or a COW
        copy of a shared tail block) was needed but none was free (caller
        must preempt)."""
        alloc = self._seqs[seq_id]
        if alloc.num_tokens % self.block_size == 0:
            if not self.free_blocks:
                return False
            alloc.block_table.append(self._acquire(1)[0])
            self._table_append(seq_id, alloc.block_table[-1:],
                               len(alloc.block_table) - 1)
        elif self._write_needs_cow(alloc):
            if not self.free_blocks:
                return False
            self._cow(seq_id, len(alloc.block_table) - 1)
        alloc.num_tokens += 1
        return True

    def free(self, seq_id: int) -> None:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is not None:
            for b in alloc.block_table:
                self._release_block(b)
            self._table_clear(seq_id)

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].block_table)

    def seq_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def has(self, seq_id: int) -> bool:
        return seq_id in self._seqs

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self._cached.clear()
        self._seqs.clear()
        self._seq_rows.clear()
        self._ref[:] = 0
        self._pins.clear()
        self._index.clear()
        self._block_key.clear()
        self._cow_ops.clear()
        self.epoch += 1
        if self._table is not None:
            self._table[:] = self.num_blocks
        self.table_version += 1

    # ------------------------------------------------------------------
    # prefix index: content-addressed full blocks
    # ------------------------------------------------------------------
    def register_prefix(self, seq_id: int, tokens: Sequence[int],
                        upto_tokens: int) -> int:
        """Publish ``seq_id``'s full leading blocks whose token contents
        (``tokens``, the prompt) are computed up to ``upto_tokens``.
        Idempotent; returns the number of registered leading blocks.

        Registration stops at the first key already claimed by a DIFFERENT
        physical chain (duplicate content computed concurrently): deeper
        blocks of this chain would be unreachable from the index root, so
        publishing them would only leak entries."""
        alloc = self._seqs[seq_id]
        bs = self.block_size
        n_full = min(int(upto_tokens), alloc.num_tokens, len(tokens)) // bs
        n_full = min(n_full, len(alloc.block_table))
        i = alloc.registered
        while i < n_full:
            b = alloc.block_table[i]
            if b in self._block_key:        # already published (shared chain)
                i += 1
                continue
            parent = alloc.block_table[i - 1] if i > 0 else -1
            key = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            if key in self._index:
                break
            self._index[key] = b
            self._block_key[b] = key
            i += 1
        alloc.registered = i
        return i

    def match_prefix(self, tokens: Sequence[int],
                     max_tokens: Optional[int] = None) -> List[int]:
        """Longest indexed chain of full blocks covering a leading run of
        ``tokens``.  Capped at ``max_tokens`` (default ``len(tokens) - 1``:
        at least one prompt token must still run prefill so the final chunk
        produces the first-token logits)."""
        toks = tokens
        if max_tokens is None:
            max_tokens = max(len(toks) - 1, 0)
        bs = self.block_size
        n_full = min(len(toks), max_tokens) // bs
        parent = -1
        out: List[int] = []
        for i in range(n_full):
            key = (parent, tuple(int(t) for t in toks[i * bs:(i + 1) * bs]))
            b = self._index.get(key)
            if b is None:
                break
            out.append(b)
            parent = b
        return out

    def share_prefix(self, seq_id: int, num_tokens: int,
                     shared_blocks: Sequence[int], *,
                     respect_watermark: bool = True) -> List[int]:
        """Attach a fresh sequence to an existing indexed chain: refcount+1
        on each shared block (no copies), fresh blocks for the private tail
        up to ``num_tokens``.  ``shared_blocks`` must be a chain returned by
        ``match_prefix`` (live, full blocks)."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        shared = list(shared_blocks)
        need = self.blocks_needed(num_tokens) - len(shared)
        assert need >= 0, (num_tokens, len(shared))
        reserve = self.watermark_blocks if respect_watermark else 0
        # cached matched blocks are revived (leave the allocatable pool)
        # rather than consumed, so they reduce capacity without reducing
        # need — same arithmetic the engine's can_allocate uses when it
        # counts only live matched blocks as shared
        cached_shared = sum(1 for b in shared if self._ref[b] == 0)
        if need > self.free_blocks - cached_shared - reserve:
            raise OutOfBlocksError(
                f"need {need} fresh blocks, "
                f"{self.free_blocks - cached_shared} free"
                + (f" ({reserve} reserved by watermark)" if reserve else ""))
        for b in shared:
            if self._ref[b] == 0:       # revive from the freed-block cache
                del self._cached[b]
                self._ref[b] = 1
            else:
                self._ref[b] += 1
        blocks = shared + self._acquire(need)
        self._seqs[seq_id] = SeqAlloc(block_table=blocks,
                                      num_tokens=num_tokens,
                                      registered=len(shared))
        return blocks

    def fork(self, src_seq_id: int, new_seq_id: int) -> List[int]:
        """Clone a live sequence: the new sequence shares EVERY block of the
        source (refcount+1 each, no copies).  A partial tail block is
        copy-on-written for the new sequence immediately so the two decodes
        never scatter into the same page."""
        if new_seq_id in self._seqs:
            raise KeyError(f"seq {new_seq_id} already allocated")
        src = self._seqs[src_seq_id]
        tail_partial = bool(src.block_table) \
            and src.num_tokens % self.block_size != 0
        if tail_partial and not self.free_blocks:
            raise OutOfBlocksError("fork needs one free block for the COW "
                                   "copy of the partial tail block")
        for b in src.block_table:
            self._ref[b] += 1
        self._seqs[new_seq_id] = SeqAlloc(block_table=list(src.block_table),
                                          num_tokens=src.num_tokens,
                                          registered=src.registered)
        if tail_partial:
            self._cow(new_seq_id, len(src.block_table) - 1)
        return list(self._seqs[new_seq_id].block_table)

    # ------------------------------------------------------------------
    # eviction under shared ownership
    # ------------------------------------------------------------------
    def shared_prefix_len(self, seq_id: int) -> int:
        """Leading blocks of ``seq_id`` that another owner also references
        (refcount > 1) — the run ``evict_split`` will pin instead of free.
        Refcounts are non-increasing along a chain (sharing only ever
        attaches prefixes; COW peels the first divergent block), so the
        leading run is exactly the shared region."""
        n = 0
        for b in self._seqs[seq_id].block_table:
            if self._ref[b] > 1:
                n += 1
            else:
                break
        return n

    def evict_split(self, seq_id: int) -> Tuple[List[int], List[int]]:
        """Evict ``seq_id`` keeping shared blocks alive: returns
        ``(pinned, private)``.  ``pinned`` blocks keep this sequence's
        reference as a snapshot pin (NOT freed, NOT copied — they stay in
        the prefix index and matchable); ``private`` blocks are released
        (the engine snapshots their page contents).  With no sharing in
        play this degenerates to ``([], all_blocks)`` == ``free``."""
        k = self.shared_prefix_len(seq_id)
        alloc = self._seqs.pop(seq_id)
        pinned = alloc.block_table[:k]
        private = alloc.block_table[k:]
        for b in pinned:
            self._pins[b] = self._pins.get(b, 0) + 1
        for b in private:
            self._release_block(b)
        self._table_clear(seq_id)
        return pinned, private

    def resume_pinned(self, seq_id: int, pinned_blocks: Sequence[int],
                      num_tokens: int, *,
                      respect_watermark: bool = True) -> List[int]:
        """Re-create an evicted sequence from its snapshot: the pinned chain
        transfers back (pin -> sequence reference, no copies) and fresh
        blocks cover the private remainder, which the engine re-scatters
        from the snapshot."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        pinned = list(pinned_blocks)
        for b in pinned:
            assert self._pins.get(b, 0) >= 1 and self._ref[b] >= 1, b
        need = self.blocks_needed(num_tokens) - len(pinned)
        assert need >= 0, (num_tokens, len(pinned))
        reserve = self.watermark_blocks if respect_watermark else 0
        if need > self.free_blocks - reserve:
            raise OutOfBlocksError(
                f"need {need} fresh blocks, {self.free_blocks} free"
                + (f" ({reserve} reserved by watermark)" if reserve else ""))
        for b in pinned:
            self._pins[b] -= 1
            if self._pins[b] == 0:
                del self._pins[b]
        blocks = pinned + self._acquire(need)
        self._seqs[seq_id] = SeqAlloc(block_table=blocks,
                                      num_tokens=num_tokens)
        return blocks

    def release_pins(self, blocks: Sequence[int], epoch: int) -> None:
        """Drop a snapshot's pins (the snapshot will never resume HERE —
        discarded, or resumed on another engine).  ``epoch`` must be the
        pool epoch recorded at eviction: after a ``reset`` the pins are
        already dead and this is a no-op."""
        if epoch != self.epoch:
            return
        for b in blocks:
            self._pins[b] -= 1
            if self._pins[b] == 0:
                del self._pins[b]
            self._release_block(b)
