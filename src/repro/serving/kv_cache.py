"""Paged KV-cache block manager (PagedAttention-style accounting).

This manager owns the **allocation state machine** the iteration scheduler
uses for admission / preemption decisions: a free list of fixed-size
blocks, a per-sequence block table, and token-capacity queries.  The
paper's RWT estimator consumes ``GPU`` (total token capacity) from here
(Appendix A.1, Eq. 16).

Under the dense attention backends the block ids are pure bookkeeping (the
KV lives in per-slot ``(B, KVH, S, D)`` arrays); under the paged backends
(``attention_backend="paged-*"``) each id names a PHYSICAL page of the
global pool ``(num_blocks, KVH, block_size, D)`` — freeing a sequence
makes its HBM immediately reusable by any other sequence.

The manager can additionally maintain an **incremental slot table**
(``attach_slot_table``): a persistent fixed-shape ``(rows, width)`` int32
array mapping engine slots to physical page ids, updated in place by every
allocate/extend/append_token/free instead of being rebuilt O(rows x width)
in Python each engine iteration.  ``table_version`` bumps on every table
mutation so the engine refreshes its device copy only when something
actually changed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


class OutOfBlocksError(RuntimeError):
    pass


@dataclasses.dataclass
class SeqAlloc:
    block_table: List[int]
    num_tokens: int


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 watermark: float = 0.01):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        # reserve a small watermark so decode appends don't immediately OOM
        self.watermark_blocks = max(1, int(num_blocks * watermark))
        self._free: List[int] = list(range(num_blocks))
        self._seqs: Dict[int, SeqAlloc] = {}
        # incremental slot table (attach_slot_table): row per engine slot,
        # sentinel num_blocks for unallocated logical blocks / unbound rows
        self._table: Optional[np.ndarray] = None
        self._seq_rows: Dict[int, int] = {}
        self.table_version = 0

    # ------------------------------------------------------------------
    # incremental slot table
    # ------------------------------------------------------------------
    def attach_slot_table(self, rows: int, width: int) -> None:
        """Maintain a persistent ``(rows, width)`` int32 slot -> physical
        page table.  Row ``r`` mirrors the block table of the sequence bound
        to it via ``bind_slot``; unbound rows and unallocated logical blocks
        hold the sentinel ``num_blocks`` (writes dropped, reads masked).
        Every subsequent allocate/extend/append_token/free updates the table
        in place — O(new blocks) instead of an O(rows x width) rebuild."""
        self._table = np.full((rows, width), self.num_blocks, np.int32)
        self._seq_rows.clear()
        self.table_version += 1

    def bind_slot(self, seq_id: int, row: int) -> None:
        """Bind an allocated sequence to a table row (engine slot) and
        populate the row from its current block table."""
        if self._table is None:
            return
        assert seq_id in self._seqs, seq_id
        self._seq_rows[seq_id] = row
        blocks = self._seqs[seq_id].block_table
        assert len(blocks) <= self._table.shape[1], \
            (len(blocks), self._table.shape)
        self._table[row, :len(blocks)] = blocks
        self._table[row, len(blocks):] = self.num_blocks
        self.table_version += 1

    def _table_append(self, seq_id: int, new_blocks: List[int],
                      start: int) -> None:
        """Record blocks just appended to ``seq_id``'s block table at
        logical positions [start, start + len(new_blocks))."""
        if self._table is None or not new_blocks:
            return
        row = self._seq_rows.get(seq_id)
        if row is None:
            return
        assert start + len(new_blocks) <= self._table.shape[1], \
            (start, len(new_blocks), self._table.shape)
        self._table[row, start:start + len(new_blocks)] = new_blocks
        self.table_version += 1

    def _table_clear(self, seq_id: int) -> None:
        row = self._seq_rows.pop(seq_id, None)
        if self._table is not None and row is not None:
            self._table[row, :] = self.num_blocks
            self.table_version += 1

    def slot_table(self) -> Optional[np.ndarray]:
        """The incrementally-maintained table (None until attached).  The
        caller must treat it as read-only; it is mutated in place by the
        allocation state machine."""
        return self._table

    # ------------------------------------------------------------------
    @property
    def token_capacity(self) -> int:
        return self.num_blocks * self.block_size

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def tokens_allocated(self) -> int:
        return sum(s.num_tokens for s in self._seqs.values())

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, num_tokens: int, *, respect_watermark: bool = True,
                     reserve_blocks: int = 0) -> bool:
        """``reserve_blocks``: extra blocks already promised elsewhere (e.g.
        the unallocated remainder of mid-prefill sequences)."""
        need = self.blocks_needed(num_tokens)
        reserve = self.watermark_blocks if respect_watermark else 0
        return need <= len(self._free) - reserve - reserve_blocks

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, num_tokens: int, *,
                 respect_watermark: bool = True) -> List[int]:
        """Allocate a fresh sequence's blocks.

        ``respect_watermark`` defaults to True so an admission-time
        ``can_allocate`` check and the allocation it green-lights enforce
        the SAME bound — previously ``allocate`` ignored the watermark and
        could silently eat the reserve ``can_allocate`` had just refused to
        touch.  Pass False only for allocations that are allowed to dip
        into the reserve (mirroring ``extend`` / ``append_token``, which
        never apply it to in-flight sequences).
        """
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.blocks_needed(num_tokens)
        reserve = self.watermark_blocks if respect_watermark else 0
        if need > len(self._free) - reserve:
            raise OutOfBlocksError(
                f"need {need} blocks, {len(self._free)} free"
                + (f" ({reserve} reserved by watermark)" if reserve else ""))
        blocks = [self._free.pop() for _ in range(need)]
        self._seqs[seq_id] = SeqAlloc(block_table=blocks, num_tokens=num_tokens)
        return blocks

    def extend(self, seq_id: int, num_tokens: int) -> bool:
        """Grow ``seq_id``'s allocation to cover ``num_tokens`` total.

        Chunk-granular prefill allocates one chunk at a time instead of the
        whole prompt up front; each subsequent chunk extends the allocation.
        Returns False when the needed blocks aren't free (caller preempts) —
        like ``append_token``, the watermark is not applied to in-flight
        sequences.
        """
        alloc = self._seqs[seq_id]
        if num_tokens <= alloc.num_tokens:
            return True
        need = self.blocks_needed(num_tokens) - len(alloc.block_table)
        if need > len(self._free):
            return False
        start = len(alloc.block_table)
        for _ in range(need):
            alloc.block_table.append(self._free.pop())
        alloc.num_tokens = num_tokens
        self._table_append(seq_id, alloc.block_table[start:], start)
        return True

    def append_token(self, seq_id: int) -> bool:
        """Account one more token; returns False if a new block was needed
        but none was free (caller must preempt)."""
        alloc = self._seqs[seq_id]
        if alloc.num_tokens % self.block_size == 0:
            if not self._free:
                return False
            alloc.block_table.append(self._free.pop())
            self._table_append(seq_id, alloc.block_table[-1:],
                               len(alloc.block_table) - 1)
        alloc.num_tokens += 1
        return True

    def free(self, seq_id: int) -> None:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is not None:
            self._free.extend(alloc.block_table)
            self._table_clear(seq_id)

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].block_table)

    def seq_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def has(self, seq_id: int) -> bool:
        return seq_id in self._seqs

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self._seqs.clear()
        self._seq_rows.clear()
        if self._table is not None:
            self._table[:] = self.num_blocks
        self.table_version += 1
