"""Paged KV-cache block manager (PagedAttention-style accounting).

This manager owns the **allocation state machine** the iteration scheduler
uses for admission / preemption decisions: a free list of fixed-size
blocks, a per-sequence block table, and token-capacity queries.  The
paper's RWT estimator consumes ``GPU`` (total token capacity) from here
(Appendix A.1, Eq. 16).

Under the dense attention backends the block ids are pure bookkeeping (the
KV lives in per-slot ``(B, KVH, S, D)`` arrays); under the paged backends
(``attention_backend="paged-*"``) each id names a PHYSICAL page of the
global pool ``(num_blocks, KVH, block_size, D)`` — freeing a sequence
makes its HBM immediately reusable by any other sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


class OutOfBlocksError(RuntimeError):
    pass


@dataclasses.dataclass
class SeqAlloc:
    block_table: List[int]
    num_tokens: int


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 watermark: float = 0.01):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        # reserve a small watermark so decode appends don't immediately OOM
        self.watermark_blocks = max(1, int(num_blocks * watermark))
        self._free: List[int] = list(range(num_blocks))
        self._seqs: Dict[int, SeqAlloc] = {}

    # ------------------------------------------------------------------
    @property
    def token_capacity(self) -> int:
        return self.num_blocks * self.block_size

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def tokens_allocated(self) -> int:
        return sum(s.num_tokens for s in self._seqs.values())

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, num_tokens: int, *, respect_watermark: bool = True,
                     reserve_blocks: int = 0) -> bool:
        """``reserve_blocks``: extra blocks already promised elsewhere (e.g.
        the unallocated remainder of mid-prefill sequences)."""
        need = self.blocks_needed(num_tokens)
        reserve = self.watermark_blocks if respect_watermark else 0
        return need <= len(self._free) - reserve - reserve_blocks

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, num_tokens: int, *,
                 respect_watermark: bool = True) -> List[int]:
        """Allocate a fresh sequence's blocks.

        ``respect_watermark`` defaults to True so an admission-time
        ``can_allocate`` check and the allocation it green-lights enforce
        the SAME bound — previously ``allocate`` ignored the watermark and
        could silently eat the reserve ``can_allocate`` had just refused to
        touch.  Pass False only for allocations that are allowed to dip
        into the reserve (mirroring ``extend`` / ``append_token``, which
        never apply it to in-flight sequences).
        """
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.blocks_needed(num_tokens)
        reserve = self.watermark_blocks if respect_watermark else 0
        if need > len(self._free) - reserve:
            raise OutOfBlocksError(
                f"need {need} blocks, {len(self._free)} free"
                + (f" ({reserve} reserved by watermark)" if reserve else ""))
        blocks = [self._free.pop() for _ in range(need)]
        self._seqs[seq_id] = SeqAlloc(block_table=blocks, num_tokens=num_tokens)
        return blocks

    def extend(self, seq_id: int, num_tokens: int) -> bool:
        """Grow ``seq_id``'s allocation to cover ``num_tokens`` total.

        Chunk-granular prefill allocates one chunk at a time instead of the
        whole prompt up front; each subsequent chunk extends the allocation.
        Returns False when the needed blocks aren't free (caller preempts) —
        like ``append_token``, the watermark is not applied to in-flight
        sequences.
        """
        alloc = self._seqs[seq_id]
        if num_tokens <= alloc.num_tokens:
            return True
        need = self.blocks_needed(num_tokens) - len(alloc.block_table)
        if need > len(self._free):
            return False
        for _ in range(need):
            alloc.block_table.append(self._free.pop())
        alloc.num_tokens = num_tokens
        return True

    def append_token(self, seq_id: int) -> bool:
        """Account one more token; returns False if a new block was needed
        but none was free (caller must preempt)."""
        alloc = self._seqs[seq_id]
        if alloc.num_tokens % self.block_size == 0:
            if not self._free:
                return False
            alloc.block_table.append(self._free.pop())
        alloc.num_tokens += 1
        return True

    def free(self, seq_id: int) -> None:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is not None:
            self._free.extend(alloc.block_table)

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].block_table)

    def seq_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def has(self, seq_id: int) -> bool:
        return seq_id in self._seqs

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self._seqs.clear()
