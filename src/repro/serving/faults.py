"""Seeded, deterministic fault injection for the serving stack (QLM §4:
the global queue is the durable request store that makes engine failure
survivable — this module is the harness that tests the claim).

``FaultPlan`` is a replayable schedule of faults.  Determinism comes from
counting, not clocks: every fault site keys on a per-(engine, site)
**occurrence counter** (the Nth decode round of engine 1, the 2nd model
swap of engine 0, ...), and probabilistic specs draw from a per-spec
``random.Random`` seeded from the plan seed — so the same seed against
the same request schedule produces the identical fault timeline, and a
chaos failure reproduces from its seed alone.

``FaultyEngine`` wraps a ``ContinuousBatchingEngine`` by composition
(attribute access delegates both ways, so ``QLMAgent`` binding
``engine.pull_source`` through the wrapper reaches the real engine).  It
interposes on the fault sites:

  * ``decode`` / ``prefill`` — fired at a ``step()``/``steps()`` round
    boundary while decode-ready / mid-prefill slots are resident, i.e.
    the crash lands with live KV allocations and in-flight requests;
  * ``swap`` — fired on ``swap_model`` entry;
  * ``materialize`` — fired when the engine promotes pinned snapshots
    (``_materialize_pinned_snapshots``), the pool-reset path PR 5 gates;
  * ``round`` — any round boundary; used for delay injection (slow-node
    emulation) independent of slot state.

Fault kinds: ``crash`` marks the engine dead and raises
``EngineCrashed`` — every later call raises ``EngineDead`` (a crashed
host does not come back); ``error`` raises ``TransientEngineError``
without killing the engine (the supervision layer's strike counter
decides); ``delay`` stalls this engine's rounds for ``delay_s`` on the
engine's injected clock (degraded, not failed — no ``time.sleep``, so a
virtual-clock driver keeps advancing and sibling engines keep serving);
``hang``
wedges the engine WITHOUT raising — every later round consumes its
quantum and makes zero progress (no tokens, no completions, no
exception), which is invisible to success-only heartbeats and exactly
what the controller's round watchdog
(``QLMConfig.hang_grace_rounds``) exists to catch.

The supervision consumer is ``QLMController.report_engine_failure`` +
``mark_dead`` (``core/qlm.py``); the chaos driver is
``launch/chaos.py``.  See ``docs/fault_tolerance.md``.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Optional, Tuple

FAULT_SITES = ("decode", "prefill", "swap", "materialize", "round")
FAULT_KINDS = ("crash", "error", "delay", "hang")


class EngineFailure(RuntimeError):
    """Base of every injected / detected engine failure.  ``fatal`` tells
    the supervision layer whether the engine is gone (crash) or merely
    misbehaving (transient error -> strike counter)."""
    fatal = False


class EngineCrashed(EngineFailure):
    """The engine died mid-operation: resident slots, KV pool, and any
    host snapshots pinned in its pool are lost with it."""
    fatal = True


class EngineDead(EngineFailure):
    """An operation reached an engine that already crashed (the caller
    missed or ignored the death notice)."""
    fatal = True


class TransientEngineError(EngineFailure):
    """A recoverable per-round failure (spurious device error, timeout):
    the round produced nothing, but the engine state is intact."""
    fatal = False


@dataclasses.dataclass
class FaultSpec:
    """One fault rule.  ``at_count`` schedules it at the Nth occurrence
    (1-based) of ``site`` on ``engine`` (``None`` = any engine);
    ``prob`` makes it probabilistic per occurrence instead.  A spec fires
    at most ``max_fires`` times."""
    site: str
    kind: str = "crash"
    engine: Optional[int] = None
    at_count: Optional[int] = None
    prob: float = 0.0
    delay_s: float = 0.0
    max_fires: int = 1

    def __post_init__(self) -> None:
        if self.site not in FAULT_SITES:
            raise ValueError(f"site must be one of {FAULT_SITES}, "
                             f"got {self.site!r}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if self.at_count is None and self.prob <= 0.0:
            raise ValueError("spec needs at_count or prob > 0")


class FaultPlan:
    """A replayable fault schedule: ask ``fire(engine_id, site)`` at every
    fault site; it returns the matching ``FaultSpec`` (or ``None``) and
    records the decision in ``events`` — the fault timeline."""

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self._counts: Dict[Tuple[int, str], int] = {}
        self._fires: Dict[int, int] = {}
        # one RNG per spec: firing (or not) of one probabilistic spec must
        # not shift another spec's draw sequence
        self._rngs = [random.Random((seed << 8) ^ i)
                      for i in range(len(self.specs))]
        self.events: List[Dict[str, Any]] = []

    def fresh(self) -> "FaultPlan":
        """A reset copy (same specs, same seed) for replaying the run."""
        return FaultPlan(list(self.specs), self.seed)

    def occurrences(self, engine_id: int, site: str) -> int:
        return self._counts.get((engine_id, site), 0)

    def fire(self, engine_id: int, site: str) -> Optional[FaultSpec]:
        n = self._counts.get((engine_id, site), 0) + 1
        self._counts[(engine_id, site)] = n
        for i, spec in enumerate(self.specs):
            if spec.site != site:
                continue
            if spec.engine is not None and spec.engine != engine_id:
                continue
            if self._fires.get(i, 0) >= spec.max_fires:
                continue
            hit = (n == spec.at_count) if spec.at_count is not None \
                else (self._rngs[i].random() < spec.prob)
            if not hit:
                continue
            self._fires[i] = self._fires.get(i, 0) + 1
            self.events.append({
                "seq": len(self.events), "engine": engine_id, "site": site,
                "kind": spec.kind, "occurrence": n, "spec": i,
            })
            return spec
        return None

    def timeline(self) -> List[Dict[str, Any]]:
        return list(self.events)

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "specs": [dataclasses.asdict(s) for s in self.specs],
            "events": self.events,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        """Rebuild a FRESH plan (counters zeroed) from a ``to_json``
        artifact, so a CI chaos timeline replays locally verbatim.  The
        recorded ``events`` are intentionally dropped: determinism means
        re-running the specs from the seed regenerates them."""
        data = json.loads(text)
        spec_fields = {f.name for f in dataclasses.fields(FaultSpec)}
        specs = [FaultSpec(**{k: v for k, v in s.items() if k in spec_fields})
                 for s in data.get("specs", [])]
        return cls(specs, seed=int(data.get("seed", 0)))


# Fields the wrapper keeps for itself; everything else delegates to the
# wrapped engine (both get and set — the agent assigns
# ``engine.pull_source`` through the wrapper).
_OWN_FIELDS = ("_engine", "_plan", "engine_id", "dead", "hung",
               "stalled_until", "_inner_materialize")


class FaultyEngine:
    """Fault-injecting proxy around a ``ContinuousBatchingEngine``.

    Pure composition — no engine methods are inherited, so the static
    lint's hot-path anchors stay on the real engine class and the
    invariant hooks (which patch ``ContinuousBatchingEngine`` methods)
    keep firing on the delegated calls.
    """

    def __init__(self, engine: Any, plan: FaultPlan, engine_id: int):
        object.__setattr__(self, "_engine", engine)
        object.__setattr__(self, "_plan", plan)
        object.__setattr__(self, "engine_id", engine_id)
        object.__setattr__(self, "dead", False)
        object.__setattr__(self, "hung", False)
        # delay faults stall rounds until this point on the ENGINE's
        # injected clock — never a raw time.sleep, which under the chaos
        # soak's shared virtual clock would block the whole round-robin
        # loop (every engine) without ever advancing the simulated
        # schedule.  Clock-gated, only this engine's rounds go empty;
        # under a threaded wall-clock loop only this agent thread idles.
        object.__setattr__(self, "stalled_until", 0.0)
        # the materialize site lives INSIDE engine paths (swap_model, the
        # admit pool-pressure valve), so it is hooked on the instance
        object.__setattr__(self, "_inner_materialize",
                           engine._materialize_pinned_snapshots)
        engine._materialize_pinned_snapshots = self._materialize_hook

    # -- delegation --------------------------------------------------------
    def __getattr__(self, name: str) -> Any:
        return getattr(object.__getattribute__(self, "_engine"), name)

    def __setattr__(self, name: str, value: Any) -> None:
        if name in _OWN_FIELDS:
            object.__setattr__(self, name, value)
        else:
            setattr(object.__getattribute__(self, "_engine"), name, value)

    # -- fault application -------------------------------------------------
    def _apply(self, spec: FaultSpec, site: str) -> None:
        n = self._plan.occurrences(self.engine_id, site)
        if spec.kind == "delay":
            # degraded, not failed: rounds return empty until the
            # engine's own clock passes the stall deadline (see
            # ``stalled_until`` in __init__ for why not time.sleep)
            now = self._engine.clock()
            until = max(self.stalled_until, now) + spec.delay_s
            self.stalled_until = until
            return
        if spec.kind == "hang":
            # the wedge: no exception, no progress — rounds from here on
            # consume their quantum and return nothing, so success-only
            # heartbeats keep firing while the engine strands its work
            self.hung = True
            return
        if spec.kind == "crash":
            self.dead = True
            raise EngineCrashed(
                f"engine {self.engine_id} crashed at {site} "
                f"(occurrence {n})")
        raise TransientEngineError(
            f"engine {self.engine_id} transient error at {site} "
            f"(occurrence {n})")

    def _check(self, site: str) -> None:
        spec = self._plan.fire(self.engine_id, site)
        if spec is not None:
            self._apply(spec, site)

    def _pre_round(self) -> bool:
        """Fault-site gate at a round boundary.  Returns True when the
        round must stall (hung engine): the caller returns an empty
        round instead of dispatching.  Once hung, occurrence counters
        freeze too — a wedged engine stops reaching its fault sites,
        which keeps the timeline replayable."""
        if self.dead:
            raise EngineDead(f"engine {self.engine_id} is dead")
        if self.hung:
            return True
        if self.stalled_until and self._engine.clock() < self.stalled_until:
            # mid-delay: this engine's round goes empty; counters freeze
            # (like hang) so the fault timeline stays clock-independent
            return True
        self._check("round")
        eng = self._engine
        if eng.prefilling_slots():
            self._check("prefill")
        elif eng.decode_slots():
            self._check("decode")
        return self.hung

    def _materialize_hook(self) -> None:
        if self.dead:
            raise EngineDead(f"engine {self.engine_id} is dead")
        self._check("materialize")
        self._inner_materialize()

    # -- interposed engine surface ----------------------------------------
    def step(self):
        if self._pre_round():
            return []
        return self._engine.step()

    def steps(self, k: Optional[int] = None):
        if self._pre_round():
            return []
        return self._engine.steps(k)

    def swap_model(self, model, params, model_name: str):
        if self.dead:
            raise EngineDead(f"engine {self.engine_id} is dead")
        if self.hung:
            return []   # a wedged engine executes nothing, swaps included
        self._check("swap")
        return self._engine.swap_model(model, params, model_name)

    def cancel_request(self, req) -> bool:
        # a dead engine holds nothing cancellable: its state died with it
        # (the supervision layer's abandon() reclaimed the accounting)
        if self.dead:
            return False
        return self._engine.cancel_request(req)
