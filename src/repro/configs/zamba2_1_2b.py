"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

38 mamba2 blocks with a shared (weight-tied) GQA attention block interleaved
every ``hybrid_attn_every`` layers.  In long-context (500k) mode the shared
attention runs sliding-window (hardware adaptation, see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4, chunk_size=64),
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
