"""whisper-medium — encoder-decoder, conv frontend stubbed. [arXiv:2212.04356]

input_specs() provides precomputed frame embeddings (batch, 1500, d_model)
for the encoder; num_layers refers to the DECODER stack (24); the encoder has
its own 24 layers per EncoderConfig.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    encoder=EncoderConfig(num_layers=24, num_frames=1500),
    source="arXiv:2212.04356",
)
