"""deepseek-67b — dense llama-arch, GQA kv=8. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    source="arXiv:2401.02954",
)
