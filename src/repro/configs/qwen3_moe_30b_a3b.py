"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,  # qwen3 uses fixed 128-dim heads with q/k norm
    d_ff=768,      # per-expert FFN width (fine-grained experts)
    vocab_size=151936,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff_expert=768),
    source="hf:Qwen/Qwen3-30B-A3B",
)
