"""Architecture registry: ``--arch <id>`` resolution for launchers."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import INPUT_SHAPES, INPUT_SHAPES_BY_NAME, InputShape, ModelConfig
from repro.configs.granite_3_2b import CONFIG as GRANITE_3_2B
from repro.configs.qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from repro.configs.h2o_danube_1_8b import CONFIG as H2O_DANUBE_1_8B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from repro.configs.qwen1_5_32b import CONFIG as QWEN1_5_32B
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        GRANITE_3_2B,
        QWEN3_MOE_30B_A3B,
        H2O_DANUBE_1_8B,
        DEEPSEEK_67B,
        ZAMBA2_1_2B,
        QWEN1_5_32B,
        MAMBA2_130M,
        LLAVA_NEXT_34B,
        DBRX_132B,
        WHISPER_MEDIUM,
    )
}


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}") from None


def get_shape(name: str) -> InputShape:
    try:
        return INPUT_SHAPES_BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown input shape {name!r}; available: {[s.name for s in INPUT_SHAPES]}") from None


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Whether (arch, shape) is runnable.

    long_500k requires sub-quadratic decode (SSM / hybrid / SWA); pure
    full-attention archs skip it (DESIGN.md §Arch-applicability).
    """
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def applicable_pairs():
    for cfg in ARCHITECTURES.values():
        for shape in INPUT_SHAPES:
            yield cfg, shape, shape_applicable(cfg, shape)
