"""h2o-danube-1.8b — dense, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,  # mistral-style SWA => sub-quadratic, runs long_500k
    source="arXiv:2401.16818",
)
