from repro.configs.base import (
    INPUT_SHAPES,
    INPUT_SHAPES_BY_NAME,
    EncoderConfig,
    InputShape,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    VisionConfig,
)
from repro.configs.registry import (
    ARCHITECTURES,
    applicable_pairs,
    get_arch,
    get_shape,
    shape_applicable,
)

__all__ = [
    "INPUT_SHAPES",
    "INPUT_SHAPES_BY_NAME",
    "EncoderConfig",
    "InputShape",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "VisionConfig",
    "ARCHITECTURES",
    "applicable_pairs",
    "get_arch",
    "get_shape",
    "shape_applicable",
]
