"""llava-next-34b — VLM language backbone (anyres tiling vision stub).
[hf:llava-hf/llava-v1.6-mistral-7b-hf (arch pattern), 34B backbone]

The ViT/projector frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings (batch, num_patch_tokens, d_model) that the
backbone consumes as a prompt prefix.
"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    vision=VisionConfig(num_patch_tokens=2880),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
