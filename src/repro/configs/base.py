"""Model / run configuration dataclasses.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the full-size config is exercised only via the dry-run
(ShapeDtypeStruct lowering), while ``reduced()`` variants run on CPU in the
smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    # Capacity factor for dense (one-hot einsum) dispatch.  tokens_per_expert
    # capacity = ceil(tokens * experts_per_token / num_experts) * capacity_factor
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Aux load-balance loss weight (Switch-style).
    aux_loss_weight: float = 0.01
    # Beyond-paper perf lever (EXPERIMENTS §Perf H2): dispatch tokens in
    # data-shard-aligned groups so the scatter stays shard-local and the
    # combine lowers to one all-reduce instead of full-token all-gathers.
    # Set to the data-axis size (16) for the production mesh.
    dispatch_groups: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD configuration."""
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 64  # SSD chunked-scan block length
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) models.

    The modality frontend (mel-spectrogram + conv subsampling) is a STUB per
    the assignment: ``input_specs`` provides precomputed frame embeddings of
    shape (batch, num_frames, d_model).
    """
    num_layers: int
    num_frames: int = 1500  # whisper 30s @ 50Hz after conv stride-2


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Vision frontend stub for VLMs: precomputed patch embeddings.

    anyres tiling (llava-next): base 576 tokens + up to 4 tiles of 576.
    """
    num_patch_tokens: int = 2880  # 576 * (1 base + 4 tiles)
    patch_embed_dim: Optional[int] = None  # defaults to d_model (projector stub)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA window, None = full attention
    rope_theta: float = 10000.0
    rms_norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    # hybrid (zamba2): 1 shared attention block applied every
    # ``hybrid_attn_every`` mamba blocks.
    hybrid_attn_every: int = 6
    # max output tokens used by the RWT estimator's conservative decode bound
    max_output_tokens: int = 2048
    # ---- perf levers (EXPERIMENTS.md §Perf; defaults = paper-baseline) ----
    # q-chunked train attention: peak activation (B,KVH,G,chunk,L) instead of
    # the full (L,L) score matrix.  None = single-shot attention.
    train_attn_chunk: Optional[int] = None
    # apply a with_sharding_constraint sharding the seq dim of activations
    # over the "model" axis between transformer blocks (cuts residual memory
    # by the TP degree at the cost of boundary collectives).
    shard_activations_seq: bool = False
    # int8 KV cache with per-(seq,head) scales (beyond-paper §Perf H3):
    # halves the decode memory-roofline term; the Pallas decode kernel
    # dequantizes in VMEM, the XLA fallback dequantizes at use.
    kv_quant: bool = False
    # route attention through the Pallas kernels (flash prefill/train,
    # blocked decode incl. the fused-dequant int8 variant, paged decode /
    # prefill-chunk block-table kernels).  Default off: on CPU they execute
    # interpret=True (correct but slow); on TPU they compile via Mosaic.
    use_pallas_attention: bool = False
    # KV pages fetched per grid step by the paged Pallas kernels (decode
    # AND prefill-chunk): multi-page tiles keep MXU tiles full when
    # block_size is small.  None auto-derives from block_size
    # (kernels.paged_decode_attention.auto_pages_per_tile targets 128-row
    # tiles); engines expose it via EngineConfig.pages_per_tile.
    paged_pages_per_tile: Optional[int] = None
    # citation / provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab axis shards over
        the 16-way model mesh axis (GSPMD rejects uneven input shardings);
        padded logits are masked to -inf in ``unembed``."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:  # attention-free (ssm)
            return 0
        return self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode: SSM state, hybrid, or sliding-window."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)

    def num_attention_layers(self) -> int:
        if self.arch_type == "ssm":
            return 0
        if self.arch_type == "hybrid":
            return self.num_layers // self.hybrid_attn_every
        return self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (used for swap-time modeling + roofline)."""
        d, h = self.d_model, self.resolved_head_dim
        attn = d * (self.num_heads * h) + 2 * d * (self.num_kv_heads * h) + (self.num_heads * h) * d
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.num_experts
        else:
            ffn = 3 * d * self.d_ff  # gated (SwiGLU) MLP
        if self.arch_type == "ssm":
            per_layer = self._ssm_layer_params()
        elif self.arch_type == "hybrid":
            n_attn = self.num_attention_layers()
            n_ssm = self.num_layers - n_attn
            per_layer = 0
            total = n_ssm * self._ssm_layer_params() + n_attn * (attn + 3 * d * self.d_ff)
            emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
            return total + emb + self.num_layers * 2 * d
        else:
            per_layer = attn + ffn
        total = self.num_layers * (per_layer + 2 * d)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            enc_per_layer = 4 * d * d + 3 * d * self.d_ff + 2 * d
            total += self.encoder.num_layers * enc_per_layer
            # decoder cross-attention adds another attn block per layer
            total += self.num_layers * (4 * d * d)
        return total + emb

    def _ssm_layer_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        di = self.ssm.d_inner(d)
        nh = self.ssm.num_heads(d)
        ns = self.ssm.d_state
        in_proj = d * (2 * di + 2 * self.ssm.n_groups * ns + nh)
        conv = self.ssm.conv_width * (di + 2 * self.ssm.n_groups * ns)
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * nh + di

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        active_ffn = self.num_layers * self.moe.experts_per_token * 3 * d * self.moe.d_ff_expert
        return dense + active_ffn

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                num_heads: int = 4, num_kv_heads: Optional[int] = None,
                d_ff: Optional[int] = None, vocab_size: int = 512,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant of the SAME family (2 layers, d_model<=512,
        <=4 experts) runnable on CPU."""
        kv = num_kv_heads if num_kv_heads is not None else max(1, min(self.num_kv_heads, num_heads))
        if kv > num_heads:
            kv = num_heads
        ff = d_ff if d_ff is not None else d_model * 4
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, max_experts),
                experts_per_token=min(self.moe.experts_per_token, 2),
                d_ff_expert=d_model,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                                      head_dim=32, chunk_size=16)
        enc = None
        if self.encoder is not None:
            enc = dataclasses.replace(self.encoder, num_layers=num_layers, num_frames=16)
        vis = None
        if self.vision is not None:
            vis = dataclasses.replace(self.vision, num_patch_tokens=8)
        return dataclasses.replace(
            self, name=self.name + "-reduced", num_layers=num_layers,
            d_model=d_model, num_heads=num_heads, num_kv_heads=kv, d_ff=ff,
            vocab_size=vocab_size, head_dim=None, moe=moe, ssm=ssm,
            encoder=enc, vision=vis, hybrid_attn_every=2,
            sliding_window=(64 if self.sliding_window is not None else None),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

INPUT_SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
