"""Paper-scale SLO benchmark on the calibrated cluster simulator:
QLM vs vLLM-FCFS vs EDF vs SHEPHERD on the multi-model workload W_B
(Figs. 12/13 conditions, reduced request count).

  PYTHONPATH=src python examples/slo_benchmark.py [--requests 1000]
"""
import argparse
import time

from repro.data.workload import workload_b
from repro.sim import ClusterSimulator, profiles_for

MODELS = ["mistral-7b-ft", "llama-70b-ft1", "vicuna-13b-ft",
          "llama-70b-ft2", "vicuna-13b-ft2"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=800)
    ap.add_argument("--rate", type=float, default=25.0)
    ap.add_argument("--instances", type=int, default=4)
    args = ap.parse_args()

    print(f"W_B: {args.requests} requests @ {args.rate}/s, "
          f"{args.instances}x A100, models={len(MODELS)}")
    print(f"{'policy':10s} {'SLO':>6s} {'req/s':>7s} {'tok/s':>8s} "
          f"{'swaps':>6s} {'evict':>6s} {'util':>6s} {'wall':>6s}")
    results = {}
    for policy in ("vllm", "edf", "shepherd", "qlm"):
        reqs = workload_b(arrival_rate=args.rate, n_requests=args.requests,
                          seed=42)
        sim = ClusterSimulator(
            [profiles_for("a100", MODELS) for _ in range(args.instances)],
            policy)
        t0 = time.monotonic()
        m = sim.run(reqs)
        results[policy] = m
        print(f"{policy:10s} {m['slo_attainment']:6.1%} "
              f"{m['throughput_rps']:7.2f} {m['token_throughput']:8.0f} "
              f"{m['swaps']:6.0f} {m['evictions']:6.0f} "
              f"{m['device_utilization']:6.1%} {time.monotonic()-t0:5.1f}s")

    gain = results["qlm"]["throughput_rps"] / results["vllm"]["throughput_rps"]
    dslo = results["qlm"]["slo_attainment"] - results["vllm"]["slo_attainment"]
    print(f"\nQLM vs vLLM: {gain:.1f}x throughput, +{dslo:.0%} SLO attainment")
    print("(paper: 20-400% throughput, 40-90% SLO attainment gains)")


if __name__ == "__main__":
    main()
