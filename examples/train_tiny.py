"""End-to-end training driver (deliverable b): train a ~100M-param model
for a few hundred steps on the synthetic structured LM stream and verify
the loss drops.  On TPU the same script scales via --full + the production
mesh; on CPU we default to a ~100M reduced config.

  PYTHONPATH=src python examples/train_tiny.py [--steps 300] [--small]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--small", action="store_true",
                    help="tiny config for a fast functional check")
    args = ap.parse_args()

    if args.small:
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--layers", "2",
                "--d-model", "128", "--lr", "3e-3"]
    else:
        # ~100M params: 8 layers x d_model 768 + 512-vocab head
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--layers", "8",
                "--d-model", "768", "--lr", "1e-3",
                "--checkpoint", "/tmp/repro_train_tiny_ckpt"]
    result = train_main(argv)
    assert result["last_loss"] < result["first_loss"], \
        "training must reduce the loss"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
