"""Multi-model serving with model swapping (paper Scenario 2 / Fig. 2).

Two model families share ONE serving instance.  QLM's request groups keep
same-model requests together, so the engine swaps models a handful of
times instead of per-request (Insight #3).  Compare against a per-request
EDF order to see the thrash.

  PYTHONPATH=src python examples/multi_model_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig

MODELS = ("granite-3-2b", "h2o-danube-1.8b")


def build_registry():
    key = jax.random.key(0)
    reg = {}
    for name in MODELS:
        cfg = get_arch(name).reduced(num_layers=2, d_model=128)
        model = build_model(cfg)
        reg[name] = (model, model.init(key))
    return reg


def make_requests(n=16, seed=0):
    rng = np.random.default_rng(seed)
    now = time.monotonic()
    return [make_request(rng.integers(0, 100, size=6).tolist(),
                         MODELS[i % 2], "batch1", arrival_time=now,
                         max_new_tokens=4) for i in range(n)]


def serve(requests, use_qlm_grouping: bool):
    reg = build_registry()
    m0, p0 = reg[MODELS[0]]
    eng = ContinuousBatchingEngine(
        m0, p0, EngineConfig(max_slots=4, max_seq_len=64),
        model_name=MODELS[0])
    vq = VirtualQueue(0)
    agent = QLMAgent(eng, vq, reg)

    if use_qlm_grouping:
        hw = HardwareProfile(0.05, 0.02, 1.2, 256, swap_time=0.5,
                             model_max_tokens=8)
        info = InstanceInfo(0, {n: hw for n in MODELS}, eng.model_name, vq)
        ctrl = QLMController([info], QLMConfig(avg_batch_size=8))
        now = time.monotonic()
        for r in requests:
            ctrl.submit(r, now)
    else:
        # per-request "EDF" alternation: one singleton group per request
        groups = []
        for r in requests:
            g = RequestGroup(model=r.model, slo=r.slo)
            g.add(r)
            groups.append(g)
        vq.set_order(groups)

    while not all(r.finished() for r in requests):
        agent.run_iteration()
    return eng.stats


def main():
    s_interleaved = serve(make_requests(), use_qlm_grouping=False)
    s_qlm = serve(make_requests(seed=0), use_qlm_grouping=True)
    print(f"per-request order : {s_interleaved.model_swaps} model swaps, "
          f"{s_interleaved.swap_time:.2f}s swapping")
    print(f"QLM request groups: {s_qlm.model_swaps} model swaps, "
          f"{s_qlm.swap_time:.2f}s swapping")
    assert s_qlm.model_swaps < s_interleaved.model_swaps
    print("=> request groups amortize model swapping (Insight #3)")


if __name__ == "__main__":
    main()
