"""Quickstart: the QLM stack in ~60 lines.

Builds one real (reduced) model, wraps it in a continuous-batching engine,
submits a mixed interactive/batch workload through the QLM controller, and
prints SLO attainment.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig


def main():
    # 1. a real model (reduced granite-3-2b family) on CPU
    cfg = get_arch("granite-3-2b").reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # 2. an LLM serving instance = engine + model (Def. 2.3)
    engine = ContinuousBatchingEngine(
        model, params, EngineConfig(max_slots=4, max_seq_len=64),
        model_name="granite")

    # 3. QLM: virtual queue + LSO agent + controller with an RWT profile
    vq = VirtualQueue(0)
    agent = QLMAgent(engine, vq, {"granite": (model, params)})
    hw = HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                         inefficiency=1.2, token_capacity=256,
                         swap_time=0.1, model_max_tokens=16)
    info = InstanceInfo(0, {"granite": hw}, "granite", vq)
    controller = QLMController([info], QLMConfig(avg_batch_size=4))

    # 4. submit a burst of mixed-SLO requests
    rng = np.random.default_rng(0)
    now = time.monotonic()
    requests = []
    for i in range(12):
        slo_class = ["interactive", "batch1", "batch2"][i % 3]
        r = make_request(rng.integers(0, 100, size=8).tolist(), "granite",
                         slo_class, arrival_time=now, max_new_tokens=6)
        requests.append(r)
        controller.submit(r, now)
    print(f"submitted {len(requests)} requests in "
          f"{len(controller.groups)} request groups")

    # 5. serve until done
    while not all(r.finished() for r in requests):
        agent.run_iteration()

    for r in requests[:3]:
        print(f"req {r.req_id} [{r.slo_class:11s}] ttft={r.ttft():.3f}s "
              f"tokens={r.output_tokens}")
    print(f"SLO attainment: {controller.slo_attainment():.0%}")
    print(f"engine stats: {engine.stats}")


if __name__ == "__main__":
    main()
