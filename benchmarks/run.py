"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Raw per-figure results land
in ``experiments/bench/*.json``; the roofline table (from the dry-run
artifacts, if present) in ``experiments/roofline_table.json``.

  PYTHONPATH=src python -m benchmarks.run [--only fig12]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on figure name")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import figures, roofline

    print("name,us_per_call,derived")
    failures = 0
    for fn in figures.ALL_FIGURES:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    if not args.skip_roofline and (args.only is None or "roofline" in args.only):
        try:
            for name, us, derived in roofline.main():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"roofline,ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
