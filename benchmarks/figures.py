"""One benchmark per paper figure (Figs. 3, 9–20).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
where ``derived`` carries the figure's headline metric(s); raw results are
also dumped to ``experiments/bench/<fig>.json``.

Scales are reduced vs the paper's 80-GPU testbed (CPU-only container) but
keep the paper's RATIOS: same SLO classes, same workload mixes, same
policies, request counts 400–1000 per point.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List

import numpy as np

from repro.core.qlm import QLMConfig
from repro.core.rwt_estimator import RWTEstimator, WorkloadProfile
from repro.data.workload import workload_a, workload_b, workload_c
from repro.sim import ClusterSimulator, profiles_for
from repro.sim.profiles import DEVICE_PROFILES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")
WB_MODELS = ["mistral-7b-ft", "llama-70b-ft1", "vicuna-13b-ft",
             "llama-70b-ft2", "vicuna-13b-ft2"]
POLICIES = ("vllm", "edf", "shepherd", "qlm")


def _dump(name: str, payload) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def _row(name: str, wall_s: float, derived: str):
    return (name, f"{wall_s * 1e6:.0f}", derived)


def _run(policy, reqs, models, n_inst, device="a100", **kw):
    profs = [profiles_for(device, models) for _ in range(n_inst)]
    sim = ClusterSimulator(profs, policy, **kw)
    return sim.run(reqs)


# ---------------------------------------------------------------------------

def fig3_waiting_time_linearity() -> List:
    """Waiting time vs queue position is linear (Insight #1): run one
    saturated instance FCFS per model, regress wait on position."""
    t0 = time.monotonic()
    out = {}
    for model in ("mistral-7b", "vicuna-13b", "llama-70b"):
        reqs = workload_a(arrival_rate=500, n_requests=600, seed=0, model=model)
        for r in reqs:
            r.slo = 1e9  # pure FCFS drain, no deadline effects
        m = _run("vllm", reqs, [model], n_inst=1)
        waits = np.array([r.ttft() for r in reqs])
        # regress the QUEUED region: the first ~batch-size requests are
        # admitted immediately (wait ≈ 0) and are not queue positions.
        first_queued = int(np.argmax(waits > 2 * waits[:16].mean() + 1e-9))
        pos = np.arange(len(waits))[first_queued:]
        w = waits[first_queued:]
        A = np.vstack([pos, np.ones_like(pos)]).T
        coef, res, *_ = np.linalg.lstsq(A, w, rcond=None)
        ss_tot = float(((w - w.mean()) ** 2).sum())
        r2 = 1 - float(res[0]) / ss_tot if len(res) else 1.0
        out[model] = {"slope_s_per_req": coef[0], "r2": r2,
                      "first_queued": first_queued}
    _dump("fig3", out)
    worst = min(v["r2"] for v in out.values())
    return [_row("fig3_waiting_linearity", time.monotonic() - t0,
                 f"min_R2={worst:.3f} (paper: 0.99)")]


def fig9_10_single_model(rates=(20, 60, 160, 400)) -> List:
    """Fig. 9 (throughput @ saturating rate) + Fig. 10 (SLO vs rate), W_A.

    2 instances × 2000 requests so the queue depth far exceeds the running
    batch (the paper's regime: 'queues are created by varying arrival
    rates'); at the top rate demand ≈ 6× token throughput."""
    t0 = time.monotonic()
    out: Dict[str, Dict] = {p: {} for p in POLICIES}
    for policy in POLICIES:
        for rate in rates:
            reqs = workload_a(arrival_rate=rate, n_requests=2000, seed=1)
            m = _run(policy, reqs, ["vicuna-13b"], n_inst=2)
            out[policy][rate] = m
    _dump("fig9_10", out)
    rows = []
    sat = rates[2]
    thr = {p: out[p][sat]["throughput_rps"] for p in POLICIES}
    rows.append(_row("fig9_single_model_throughput", time.monotonic() - t0,
                     f"qlm/vllm={thr['qlm']/max(thr['vllm'],1e-9):.2f}x "
                     f"qlm/shepherd={thr['qlm']/max(thr['shepherd'],1e-9):.2f}x"))
    slo = {p: out[p][sat]["slo_attainment"] for p in POLICIES}
    rows.append(_row("fig10_single_model_slo", 0,
                     f"qlm={slo['qlm']:.2f} vllm={slo['vllm']:.2f} "
                     f"edf={slo['edf']:.2f} shepherd={slo['shepherd']:.2f}"))
    return rows


def fig11_single_model_ablation(rate=1.5) -> List:
    """Fig. 11: remove each LSO from QLM (single model => swap moot).
    A10-class KV capacity (7k tokens) so batch requests genuinely block
    interactive admissions — the paper's eviction scenario (Insight #2)."""
    t0 = time.monotonic()
    variants = {
        "qlm_full": {},
        "no_eviction": {"uses_eviction": False},
        "no_reordering": {"reorders": False},
    }
    out = {}
    for name, override in variants.items():
        reqs = workload_a(arrival_rate=rate, n_requests=400, seed=2)
        kw = {"traits_override": override} if override else {}
        out[name] = _run("qlm", reqs, ["vicuna-13b"], n_inst=2, device="a10", **kw)
    _dump("fig11", out)
    return [_row("fig11_lso_ablation_single", time.monotonic() - t0,
                 " ".join(f"{k}={v['slo_attainment']:.2f}"
                          for k, v in out.items()))]


def fig12_13_multi_model(rates=(10, 25, 50)) -> List:
    t0 = time.monotonic()
    out: Dict[str, Dict] = {p: {} for p in POLICIES}
    for policy in POLICIES:
        for rate in rates:
            reqs = workload_b(arrival_rate=rate, n_requests=700, seed=3)
            out[policy][rate] = _run(policy, reqs, WB_MODELS, n_inst=4)
    _dump("fig12_13", out)
    mid = rates[1]
    thr = {p: out[p][mid]["throughput_rps"] for p in POLICIES}
    slo = {p: out[p][mid]["slo_attainment"] for p in POLICIES}
    return [
        _row("fig12_multi_model_throughput", time.monotonic() - t0,
             f"qlm/vllm={thr['qlm']/max(thr['vllm'],1e-9):.2f}x (paper ~3-4x)"),
        _row("fig13_multi_model_slo", 0,
             f"qlm={slo['qlm']:.2f} vllm={slo['vllm']:.2f} "
             f"edf={slo['edf']:.2f} shepherd={slo['shepherd']:.2f}"),
    ]


def fig14_multi_model_ablation(rate=25) -> List:
    """2 instances < 5 models forces real model multiplexing, so the swap
    LSO contribution is visible (the paper's dominant term in Fig. 14)."""
    t0 = time.monotonic()
    variants = {
        "qlm_full": {},
        "no_eviction": {"uses_eviction": False},
        "no_swap_planning": {"plans_swaps": False},
        "no_reordering": {"reorders": False},
    }
    out = {}
    for name, override in variants.items():
        reqs = workload_b(arrival_rate=rate, n_requests=900, seed=4)
        kw = {"traits_override": override} if override else {}
        out[name] = _run("qlm", reqs, WB_MODELS, n_inst=2, **kw)
    _dump("fig14", out)
    return [_row("fig14_lso_ablation_multi", time.monotonic() - t0,
                 " ".join(f"{k}:slo={v['slo_attainment']:.2f},thr={v['throughput_rps']:.1f}"
                          for k, v in out.items()))]


def fig15_hardware_heterogeneity(rate=40) -> List:
    """A10/A100 mixes: QLM's RWT-weighted placement vs round-robin (random)."""
    t0 = time.monotonic()
    out = {}
    for frac_a10 in (0.0, 0.25, 0.5):
        n_inst = 4
        n_a10 = int(n_inst * frac_a10)
        profs = ([profiles_for("a10", ["vicuna-13b"])] * n_a10 +
                 [profiles_for("a100", ["vicuna-13b"])] * (n_inst - n_a10))
        res = {}
        for policy in ("qlm", "vllm"):  # vllm spreads least-loaded≈round-robin
            reqs = workload_a(arrival_rate=rate, n_requests=600, seed=5)
            sim = ClusterSimulator(profs, policy)
            res[policy] = sim.run(reqs)
        out[f"a10_{frac_a10}"] = res
    _dump("fig15", out)
    d = {k: v["qlm"]["throughput_rps"] / max(v["vllm"]["throughput_rps"], 1e-9)
         for k, v in out.items()}
    return [_row("fig15_heterogeneity", time.monotonic() - t0,
                 " ".join(f"{k}:qlm/rr={v:.2f}x" for k, v in d.items()))]


def fig16_mega_prompt(rate=3) -> List:
    """A10-class instances (7k-token KV) so a 4k-token mega prompt really
    does occupy most of the device — the paper's HOL-blocking setup."""
    t0 = time.monotonic()
    out = {}
    for frac in (0.0, 0.1, 0.3):
        res = {}
        for policy in ("qlm", "vllm"):
            reqs = workload_c(arrival_rate=rate, n_requests=600, seed=6,
                              mega_fraction=frac)
            res[policy] = _run(policy, reqs, ["vicuna-13b"], n_inst=4,
                               device="a10")
        out[f"mega_{frac}"] = res
    _dump("fig16", out)
    d = {k: v["qlm"]["slo_attainment"] - v["vllm"]["slo_attainment"]
         for k, v in out.items()}
    return [_row("fig16_mega_prompt", time.monotonic() - t0,
                 " ".join(f"{k}:+{v:.2f}slo" for k, v in d.items()))]


def fig17_queue_size() -> List:
    """SLO attainment vs queue size (arrival-rate sweep creates the queue)
    + the §8.3 burstiness axis (gamma interarrivals, CV=4)."""
    t0 = time.monotonic()
    out: Dict[str, Dict] = {p: {} for p in POLICIES}
    for policy in POLICIES:
        for rate in (5, 20, 60, 150):
            reqs = workload_b(arrival_rate=rate, n_requests=500, seed=7)
            out[policy][rate] = _run(policy, reqs, WB_MODELS, n_inst=4)
    # bursty variant at the mid rate
    from repro.data.workload import WorkloadSpec, generate
    bursty = {}
    for policy in ("vllm", "qlm"):
        reqs = generate(WorkloadSpec(
            name="W_B_bursty", n_requests=500, seed=7, arrival_rate=20,
            burstiness_cv=4.0,
            mix=[("batch1", "mistral-7b-ft", 0.25),
                 ("batch1", "llama-70b-ft1", 0.25),
                 ("batch2", "vicuna-13b-ft", 0.20),
                 ("batch2", "llama-70b-ft2", 0.15),
                 ("batch2", "vicuna-13b-ft2", 0.15)]))
        bursty[policy] = _run(policy, reqs, WB_MODELS, n_inst=4)
    out["bursty_cv4"] = bursty
    _dump("fig17", out)
    # the paper's claim: gap widens with queue size and persists under burst
    gap_small = out["qlm"][5]["slo_attainment"] - out["vllm"][5]["slo_attainment"]
    gap_big = out["qlm"][150]["slo_attainment"] - out["vllm"][150]["slo_attainment"]
    gap_burst = bursty["qlm"]["slo_attainment"] - bursty["vllm"]["slo_attainment"]
    return [_row("fig17_queue_size", time.monotonic() - t0,
                 f"slo_gap@rate5={gap_small:.2f} slo_gap@rate150={gap_big:.2f} "
                 f"slo_gap@bursty_cv4={gap_burst:.2f}")]


def fig18_rwt_accuracy() -> List:
    """R² of RWT waiting-time ESTIMATES (Eq. 2: q·μ_o/Θ — the estimator
    only knows the fitted output distribution, not true lengths) vs the
    simulated ground truth, per model, for growing queue sizes.

    The paper's own finding reproduces: conservative (low R²) for short
    queues where the CLT hasn't kicked in, →0.99 for long queues.
    """
    t0 = time.monotonic()
    out = {}
    for model in ("mistral-7b", "vicuna-13b", "llama-70b"):
        hw = DEVICE_PROFILES["a100"][model]
        reqs = workload_a(arrival_rate=3000, n_requests=1200, seed=8, model=model)
        for r in reqs:
            r.slo = 1e9
        # paper §6 "Hardware Profiling": ONE saturated batch run measures Θ
        # (tokens/s) — that's the only per-(model, device) calibration.
        prof_reqs = workload_a(arrival_rate=3000, n_requests=700, seed=99,
                               model=model)
        for r in prof_reqs:
            r.slo = 1e9
        prof_sim = ClusterSimulator([profiles_for("a100", [model])], "vllm",
                                    max_batch_requests=256)
        prof_sim.run(prof_reqs)
        pstats = prof_sim.instances[0].stats
        d_measured = pstats.busy_time / max(pstats.iterations, 1)  # d·ε

        profs = [profiles_for("a100", [model])]
        sim = ClusterSimulator(profs, "vllm", max_batch_requests=256)
        sim.run(reqs)
        waits = np.array([r.ttft() for r in reqs])
        wl = WorkloadProfile.fit([r.prompt_len for r in reqs],
                                 [r.true_output_tokens for r in reqs])
        b_eff = min(hw.batch_size(wl), 256.0)
        theta = b_eff / d_measured  # Eq. 15 with profiled d·ε
        # queue position = requests AHEAD IN THE WAITING QUEUE (the running
        # batch is not "the queue"; Eq. 2 counts requests ahead in queue)
        qpos = np.maximum(0.0, np.arange(len(reqs), dtype=float) - b_eff)
        preds = qpos * wl.mu_output / theta          # Eq. 2 with Eq. 3 mean
        queued = np.flatnonzero(qpos > 0)
        r2_by_q = {q: RWTEstimator.r_squared(preds[queued[:q]], waits[queued[:q]])
                   for q in (30, 100, 400, len(queued))}
        out[model] = r2_by_q
    _dump("fig18", out)
    final = {m: v[max(v)] for m, v in out.items()}
    small = {m: v[30] for m, v in out.items()}
    return [_row("fig18_rwt_accuracy", time.monotonic() - t0,
                 " ".join(f"{m}:R2={v:.3f}" for m, v in final.items()) +
                 f" | small-queue min R2={min(small.values()):.2f}")]


def fig19_group_size_delta(rate=25) -> List:
    """δ trade-off: smaller groups => finer decisions, more overhead."""
    t0 = time.monotonic()
    out = {}
    for delta in (1, 4, 16):
        reqs = workload_b(arrival_rate=rate, n_requests=600, seed=9)
        cfg = QLMConfig(avg_batch_size=32, delta=float(delta))
        t1 = time.monotonic()
        m = _run("qlm", reqs, WB_MODELS, n_inst=4, qlm_cfg=cfg)
        m["scheduler_wall_s"] = time.monotonic() - t1
        out[delta] = m
    _dump("fig19", out)
    return [_row("fig19_group_size_delta", time.monotonic() - t0,
                 " ".join(f"d{d}:slo={v['slo_attainment']:.2f}"
                          for d, v in out.items()))]


def fig20_solver_overhead() -> List:
    """Solver wall time vs queue size (groups scale with queue/δ)."""
    import random
    from repro.core.solver import GroupSpec, InstanceSpec, solve
    t0 = time.monotonic()
    rng = random.Random(0)
    out = {}
    for n_requests in (1000, 10_000, 100_000, 400_000):
        group_size = 128  # avg_batch 32 × δ 4
        n_groups = max(1, n_requests // group_size)
        instances = [InstanceSpec(i, "A", {"A": 2.0, "B": 3.0})
                     for i in range(8)]
        groups = [GroupSpec(j, rng.choice(["A", "B"]), rng.uniform(10, 3600),
                            {i: rng.uniform(1, 30) for i in range(8)})
                  for j in range(n_groups)]
        t1 = time.monotonic()
        solve(groups, instances)
        dt = time.monotonic() - t1
        out[n_requests] = {"n_groups": n_groups, "solve_s": dt,
                           "ms_per_request": dt / n_requests * 1e3}
    _dump("fig20", out)
    worst = max(v["ms_per_request"] for v in out.values())
    return [_row("fig20_solver_overhead", time.monotonic() - t0,
                 f"max_ms_per_request={worst:.3f} (paper budget: 5ms)")]


def fig1_gpus_required() -> List:
    """Fig. 1 (right): instances required to hold a >=90%-attainment SLO,
    single- and multi-model, per system.  QLM's multiplexing needs the
    fewest (the paper's 2-vs-4-GPU example)."""
    from repro.core.autoscale import find_min_instances
    from repro.data.workload import WorkloadSpec, generate
    t0 = time.monotonic()
    models = ["mistral-7b", "vicuna-13b"]

    def mk():  # Fig. 2 scenario: 2 models x (interactive + batch), tight KV
        return generate(WorkloadSpec(
            name="fig1", n_requests=400, seed=21, arrival_rate=4,
            mix=[("interactive", "mistral-7b", 0.2),
                 ("batch1", "mistral-7b", 0.15), ("batch2", "mistral-7b", 0.15),
                 ("interactive", "vicuna-13b", 0.2),
                 ("batch1", "vicuna-13b", 0.15), ("batch2", "vicuna-13b", 0.15)]))

    out = {}
    for policy in ("vllm", "shepherd", "qlm"):
        def run_with_n(n):
            return _run(policy, mk(), models, n_inst=n, device="a10")
        res = find_min_instances(run_with_n, slo_target=0.90, lo=1, hi=8)
        out[policy] = res["min_instances"]
    _dump("fig1", out)
    return [_row("fig1_gpus_required", time.monotonic() - t0,
                 " ".join(f"{p}={v if v is not None else '>8'}"
                          for p, v in out.items()) +
                 " (paper Fig.2: QLM 2 vs baseline 4)")]


def fig_chunked_prefill_ttft() -> List:
    """Beyond-paper (SLOs-Serve / chunked-prefill co-scheduling): mean
    interactive TTFT on a mixed short/long-prompt workload, lump prefill vs
    the engine's chunk-interleaved accounting (prefill_chunk_tokens)."""
    from repro.core.request import make_request

    t0 = time.monotonic()

    def mk_reqs(seed: int):
        rng = np.random.default_rng(seed)
        reqs, t = [], 0.0
        for i in range(120):
            t += float(rng.exponential(1.0 / 8.0))
            if i % 4 == 0:
                # mega-prompt batch job (the prefill stall source)
                reqs.append(make_request(list(range(4096)), "vicuna-13b",
                                         "batch2", arrival_time=t,
                                         max_new_tokens=64))
            else:
                reqs.append(make_request(list(range(int(rng.integers(16, 128)))),
                                         "vicuna-13b", "interactive",
                                         arrival_time=t, max_new_tokens=32))
        for r in reqs:
            r.true_output_tokens = r.max_new_tokens
        return reqs

    out = {}
    for mode, chunk in (("lump", None), ("chunked", 256)):
        reqs = mk_reqs(seed=7)
        kw = {"traits_override": {"prefill_chunk_tokens": chunk}} if chunk else {}
        m = _run("qlm", reqs, ["vicuna-13b"], n_inst=1, **kw)
        inter = [r.ttft() for r in reqs
                 if r.slo_class == "interactive" and r.ttft() is not None]
        out[mode] = {"mean_interactive_ttft": float(np.mean(inter)), **m}
    _dump("fig_chunked_prefill", out)
    lump = out["lump"]["mean_interactive_ttft"]
    chunked = out["chunked"]["mean_interactive_ttft"]
    return [_row("fig_chunked_prefill_ttft", time.monotonic() - t0,
                 f"interactive_ttft lump={lump:.3f}s chunked={chunked:.3f}s "
                 f"({lump / max(chunked, 1e-9):.2f}x)")]


def fig_paged_kv_capacity() -> List:
    """Beyond-paper (PagedAttention layout): engine KV cache bytes for the
    dense per-slot layout scale with max_slots * max_seq_len; the paged
    page pool's scale with kv_blocks * block_size only — a 4x-oversubscribed
    pool still serves real traffic token-identically to the dense backend."""
    import jax

    from repro.configs import ARCHITECTURES
    from repro.core.request import make_request
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine, EngineConfig

    t0 = time.monotonic()
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def cache_mb(**kw):
        eng = ContinuousBatchingEngine(model, params, EngineConfig(
            prefill_chunk_tokens=16, block_size=16, **kw), model_name="m")
        return sum(l.nbytes for l in jax.tree.leaves(eng.cache)) / 1e6, eng

    out = {"dense": {}, "paged": {}}
    for slots, seq in ((4, 256), (8, 512), (16, 1024)):
        d_mb, _ = cache_mb(max_slots=slots, max_seq_len=seq)
        p_mb, _ = cache_mb(max_slots=slots, max_seq_len=seq, kv_blocks=64,
                           attention_backend="paged-xla")
        out["dense"][f"{slots}x{seq}"] = d_mb
        out["paged"][f"{slots}x{seq}"] = p_mb

    # liveness at 4x oversubscription: 8 slots * 512 seq would need 256
    # blocks dense-equivalent; serve a workload through a 64-block pool
    p_mb, eng = cache_mb(max_slots=8, max_seq_len=512, kv_blocks=64,
                         attention_backend="paged-xla")
    rng = np.random.default_rng(0)
    reqs = [make_request(rng.integers(0, 100, size=int(n)).tolist(), "m",
                         "interactive", max_new_tokens=8)
            for n in rng.integers(8, 48, size=6)]
    queue = list(reqs)
    eng.pull_source = lambda: queue.pop(0) if queue else None
    for _ in range(200):
        eng.step()
        if all(r.finished() for r in reqs):
            break
    served = sum(r.finished() for r in reqs)
    out["oversubscribed"] = {"kv_blocks": 64, "served": served,
                             "pool_mb": p_mb}
    _dump("fig_paged_kv_capacity", out)
    d = out["dense"]
    p = out["paged"]
    return [_row("fig_paged_kv_capacity", time.monotonic() - t0,
                 f"dense_MB {d['4x256']:.1f}->{d['16x1024']:.1f} (16x) vs "
                 f"paged_MB {p['4x256']:.1f}->{p['16x1024']:.1f} (1x, "
                 f"64 blocks); 4x-oversubscribed pool served {served}/6")]


ALL_FIGURES = [
    fig1_gpus_required,
    fig3_waiting_time_linearity,
    fig9_10_single_model,
    fig11_single_model_ablation,
    fig12_13_multi_model,
    fig14_multi_model_ablation,
    fig15_hardware_heterogeneity,
    fig16_mega_prompt,
    fig17_queue_size,
    fig18_rwt_accuracy,
    fig19_group_size_delta,
    fig20_solver_overhead,
    fig_chunked_prefill_ttft,
    fig_paged_kv_capacity,
]
