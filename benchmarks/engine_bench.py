"""Engine step-loop benchmark: steps/sec + host-overhead fraction.

Measures the serving hot loop end-to-end (the thing QLM's RWT math assumes
runs at hardware speed) across backends x batch sizes x step-loop
variants:

  * ``seed``            — the pre-optimization loop: single-step dispatch,
                          no buffer donation, block table rebuilt in
                          Python and re-uploaded every round;
  * ``donated``         — buffer donation + incremental block table, still
                          single-step;
  * ``burst4/burst16``  — donation + incremental table + fused multi-step
                          dispatch (``EngineConfig.decode_burst``);
  * ``burst4_undonated``— burst without donation (isolates the two wins).

Plus a **prefix-sharing scenario** (paged backends): N requests sharing a
75%-length common prompt prefix served with ``EngineConfig.prefix_sharing``
on vs off — emitted as ``prefix_sharing`` rows carrying the prefix hit
rate, the pool blocks saved during the prompt phase (1 shared chain + N
private tails vs N full chains), and a token-parity bit (the streams must
be identical in both modes).

Per row: decode ``steps/sec`` over a measured run of ``steps()`` calls,
the median wall time of the raw jitted dispatch for the same shapes
(``jit_us_per_iter``), and the derived ``host_overhead_fraction``
(1 - jit/wall): the share of each iteration spent OUTSIDE the jitted
computation — np conversions, Python slot bookkeeping, block-table
management, dispatch latency.  On this CPU container the Pallas backends
interpret their kernels (wall times are not TPU-representative), but the
host-overhead fraction and the seed-vs-optimized RATIO are exactly the
orchestrator costs this benchmark exists to pin down.

Emits ``BENCH_engine.json``:

  PYTHONPATH=src python benchmarks/engine_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

VARIANTS = (
    # (label, decode_burst, donate_buffers, incremental_block_table)
    ("seed", 1, False, False),
    ("donated", 1, True, True),
    ("burst4", 4, True, True),
    ("burst16", 16, True, True),
    ("burst4_undonated", 4, False, True),
)


def _build(arch, num_layers, d_model):
    from repro.configs import ARCHITECTURES
    from repro.models import build_model
    cfg = ARCHITECTURES[arch].reduced(num_layers=num_layers, d_model=d_model)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _mk_engine(model, params, *, backend, batch, burst, donate, incremental,
               max_seq, prefix_sharing=True):
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    cfg = EngineConfig(max_slots=batch, max_seq_len=max_seq, block_size=8,
                       prefill_chunk_tokens=16, attention_backend=backend,
                       decode_burst=burst, donate_buffers=donate,
                       incremental_block_table=incremental,
                       prefix_sharing=prefix_sharing)
    return ContinuousBatchingEngine(model, params, cfg, model_name="bench")


def _admit_and_drain_prefill(eng, batch, prompt_len, max_new):
    from repro.core.request import Request
    rng = np.random.default_rng(7)
    reqs = [Request(prompt_tokens=rng.integers(0, 100, size=prompt_len).tolist(),
                    model="bench", slo=1e9, max_new_tokens=max_new)
            for _ in range(batch)]
    for r in reqs:
        assert eng.admit(r)
    while eng.prefilling_slots():
        eng.step()
    return reqs


def _probe_jit_us(eng, burst, probes=5):
    """Median wall microseconds of ONE raw jitted decode dispatch at the
    engine's current shapes, divided by the burst width — the pure
    dispatch+compute cost an iteration would have with zero host
    orchestration.  The probe passes fresh host arrays each call (matching
    what the step loop uploads) and rebinds the donated cache."""
    B = eng.cfg.max_slots
    tokens = np.zeros(B, np.int32)
    for i in eng.decode_slots():
        r = eng.slots[i]
        tokens[i] = r.output_tokens[-1] if r.output_tokens \
            else r.prompt_tokens[-1]
    active = np.zeros(B, bool)
    active[eng.decode_slots()] = True
    remaining = np.full(B, 1_000_000, np.int32)  # never finishes mid-probe
    samples = []
    for _ in range(probes + 1):  # first call warms any residual compile
        t0 = time.perf_counter()
        if burst > 1:
            bt = eng._device_block_table() if eng.paged else None
            out, eng.cache = eng._burst_fn(
                eng.params, eng.cache, jnp.asarray(tokens),
                jnp.asarray(eng.lengths), jnp.asarray(remaining),
                jnp.asarray(active), jnp.int32(burst), bt)
            jax.block_until_ready((out, eng.cache))
        else:
            if eng.paged:
                nxt, eng.cache = eng._decode_fn(
                    eng.params, eng.cache, jnp.asarray(tokens),
                    jnp.asarray(eng.lengths), eng._device_block_table())
            else:
                nxt, eng.cache = eng._decode_fn(
                    eng.params, eng.cache, jnp.asarray(tokens),
                    jnp.asarray(eng.lengths))
            jax.block_until_ready((nxt, eng.cache))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples[1:])) * 1e6 / burst


def bench_variant(model, params, *, backend, batch, label, burst, donate,
                  incremental, iters, max_seq):
    prompt_len = 16
    eng = _mk_engine(model, params, backend=backend, batch=batch,
                     burst=burst, donate=donate, incremental=incremental,
                     max_seq=max_seq)
    # max_new sized so no request retires during warmup + measurement
    reqs = _admit_and_drain_prefill(eng, batch, prompt_len,
                                    max_new=iters + 4 * burst + 8)
    eng.steps()  # warm the decode/burst jit before timing

    it0 = eng.stats.decode_iterations
    tok0 = eng.stats.tokens_generated
    t0 = time.perf_counter()
    while eng.stats.decode_iterations - it0 < iters:
        eng.steps()
    wall = time.perf_counter() - t0
    n_iters = eng.stats.decode_iterations - it0
    n_tokens = eng.stats.tokens_generated - tok0
    assert all(not r.finished() for r in reqs), \
        "requests retired mid-measurement (grow max_new / max_seq)"

    wall_us = wall * 1e6 / n_iters
    jit_us = _probe_jit_us(eng, burst)
    return {
        "backend": backend, "batch": batch, "variant": label,
        "decode_burst": burst, "donated": donate,
        "incremental_table": incremental,
        "steps_per_sec": round(n_iters / wall, 2),
        "tokens_per_sec": round(n_tokens / wall, 2),
        "wall_us_per_iter": round(wall_us, 1),
        "jit_us_per_iter": round(jit_us, 1),
        "host_overhead_fraction": round(max(0.0, 1.0 - jit_us / wall_us), 4),
    }


def bench_prefix_sharing(model, params, *, backend, batch=8, prompt_len=32,
                         shared_frac=0.75, max_new=8):
    """N requests sharing a ``shared_frac`` common prompt prefix, served
    with prefix sharing on vs off: hit rate, prompt-phase pool blocks
    saved, COW copies, and a token-parity check."""
    from repro.core.request import Request
    rng = np.random.default_rng(11)
    shared_len = int(prompt_len * shared_frac)
    common = rng.integers(0, 100, size=shared_len).tolist()
    prompts = [common + rng.integers(0, 100,
                                     size=prompt_len - shared_len).tolist()
               for _ in range(batch)]

    def serve(sharing):
        eng = _mk_engine(model, params, backend=backend, batch=batch,
                         burst=1, donate=True, incremental=True,
                         max_seq=prompt_len + max_new + 8,
                         prefix_sharing=sharing)
        reqs = [Request(prompt_tokens=p, model="bench", slo=1e9,
                        max_new_tokens=max_new) for p in prompts]
        # leader first: followers match the blocks its chunks publish
        assert eng.admit(reqs[0])
        while eng.prefilling_slots():
            eng.step()
        for r in reqs[1:]:
            assert eng.admit(r)
        while eng.prefilling_slots():
            eng.step()
        prompt_blocks = eng.block_mgr.used_blocks
        for _ in range(10 * max_new):
            eng.step()
            if all(r.finished() for r in reqs):
                break
        assert all(r.finished() for r in reqs)
        assert eng.block_mgr.used_blocks == 0
        return [r.output_tokens for r in reqs], prompt_blocks, eng.stats

    tokens_on, blocks_on, stats = serve(True)
    tokens_off, blocks_off, _ = serve(False)
    denom = max(stats.prompt_tokens_admitted, 1)
    return {
        "backend": backend, "batch": batch, "prompt_len": prompt_len,
        "shared_prefix_len": shared_len,
        "prefix_hits": stats.prefix_hits,
        "prefix_hit_rate": round(stats.prefix_shared_tokens / denom, 4),
        "prefix_shared_blocks": stats.prefix_shared_blocks,
        "prompt_pool_blocks_sharing": blocks_on,
        "prompt_pool_blocks_baseline": blocks_off,
        "blocks_saved": blocks_off - blocks_on,
        "cow_copies": stats.cow_copies,
        "tokens_match": tokens_on == tokens_off,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep (xla + paged-pallas at batch 4)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()

    if args.smoke:
        backends = ["xla", "paged-pallas"]
        batches = [4]
        num_layers, d_model = 1, 64
        iters = args.iters or 16
        variants = [v for v in VARIANTS if v[0] != "burst16"]
        sharing_backends = ["paged-pallas"]
    else:
        backends = ["xla", "pallas", "paged-xla", "paged-pallas"]
        batches = [1, 4, 8]
        num_layers, d_model = 2, 128
        iters = args.iters or 32
        variants = list(VARIANTS)
        sharing_backends = ["paged-xla", "paged-pallas"]

    model, params = _build("granite-3-2b", num_layers, d_model)
    max_seq = 16 + iters + 16 * 4 + 32  # prompt + run + burst slack

    t_start = time.time()
    rows = []
    for backend in backends:
        for batch in batches:
            for label, burst, donate, incremental in variants:
                row = bench_variant(model, params, backend=backend,
                                    batch=batch, label=label, burst=burst,
                                    donate=donate, incremental=incremental,
                                    iters=iters, max_seq=max_seq)
                rows.append(row)
                print(f"{backend:>12} b={batch} {label:>16}: "
                      f"{row['steps_per_sec']:>8.1f} steps/s  "
                      f"host-overhead {row['host_overhead_fraction']:.0%}")

    # shared-prompt scenario (paged backends; 8 x 75%-shared prefixes)
    sharing_rows = []
    for backend in sharing_backends:
        row = bench_prefix_sharing(model, params, backend=backend)
        sharing_rows.append(row)
        print(f"{backend:>12} prefix-sharing: hit-rate "
              f"{row['prefix_hit_rate']:.0%}, blocks "
              f"{row['prompt_pool_blocks_baseline']} -> "
              f"{row['prompt_pool_blocks_sharing']} "
              f"(saved {row['blocks_saved']}), tokens_match="
              f"{row['tokens_match']}")

    # seed-vs-optimized summary per (backend, batch)
    summary = []
    for backend in backends:
        for batch in batches:
            by = {r["variant"]: r for r in rows
                  if r["backend"] == backend and r["batch"] == batch}
            seed, burst = by.get("seed"), by.get("burst4")
            if seed and burst:
                summary.append({
                    "backend": backend, "batch": batch,
                    "burst4_speedup_vs_seed": round(
                        burst["steps_per_sec"] / seed["steps_per_sec"], 3),
                    "host_overhead_seed": seed["host_overhead_fraction"],
                    "host_overhead_burst4": burst["host_overhead_fraction"],
                })

    result = {
        "meta": {
            "backend": jax.default_backend(),
            "pallas_interpret": jax.default_backend() != "tpu",
            "model": {"arch": "granite-3-2b-reduced",
                      "num_layers": num_layers, "d_model": d_model},
            "iters": iters,
            "note": ("steps/sec at reduced scale; Pallas kernels interpret "
                     "off-TPU so absolute wall times are not "
                     "TPU-representative — the seed-vs-optimized ratio and "
                     "host_overhead_fraction are the orchestrator metrics "
                     "this file tracks per PR"),
            "wall_seconds": 0.0,
        },
        "engine": rows,
        "prefix_sharing": sharing_rows,
        "summary": summary,
    }
    result["meta"]["wall_seconds"] = round(time.time() - t_start, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({result['meta']['wall_seconds']}s)")
    for s in summary:
        print(f"{s['backend']:>12} b={s['batch']}: burst4 "
              f"{s['burst4_speedup_vs_seed']}x vs seed, host overhead "
              f"{s['host_overhead_seed']:.0%} -> "
              f"{s['host_overhead_burst4']:.0%}")


if __name__ == "__main__":
    main()
