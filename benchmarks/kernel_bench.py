"""Paged chunk-attention kernel microbenchmark: gather path vs fused.

Measures, per (prefix_len, block_size) point:

  * wall time of the XLA gather path (densify the pre-chunk page pool
    through the block table + two-segment masked softmax — exactly what
    ``attend_prefill_chunk_paged`` falls back to), and of the fused Pallas
    paged prefill-chunk kernel (``kernels/paged_prefill_attention.py``);
  * MODELED per-chunk HBM bytes for both: the gather path moves the whole
    padded pool slice three times (pool read -> densified write -> attention
    read), the fused kernel streams only the live pages once, in place.
    The model is the roofline metric here — on this CPU container the
    Pallas kernel executes in interpret mode (Python), so its wall time is
    NOT meaningful; on TPU the same call sites compile via Mosaic.

Also sweeps the paged decode kernel's multi-page kv tiles
(``pages_per_tile``) across block sizes.

Emits ``BENCH_kernels.json``:

  PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, iters: int) -> float:
    """Median wall seconds per call (after one warm/compile call)."""
    jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def modeled_chunk_hbm_bytes(*, prefix: int, table_tokens: int, bs: int,
                            chunk: int, num_q_heads: int, kv_heads: int,
                            head_dim: int, itemsize: int,
                            pages_per_tile: int, fused: bool) -> int:
    """Per-chunk-attention HBM byte model (KV + q/out/in-chunk terms).

    gather: the pool slice named by the (sentinel-padded, ``table_tokens``
    wide) block table is read, written back densified, and read again by
    the attention — 3 passes over k+v regardless of how much of the table
    is live.  fused: the kernel streams each live page once per KV head
    (the GQA group's queries ride in one tile) and tiles wholly past
    ``prefix`` keep a clamped, unchanged block index so the pipeline
    elides their DMAs — charged at tile granularity
    (``pages_per_tile * bs`` rows), minimum one tile (the clamped dead
    fetch of the first grid step).
    """
    row = kv_heads * head_dim * itemsize
    q_out = 2 * num_q_heads * chunk * head_dim * itemsize
    chunk_kv = 2 * chunk * row
    if fused:
        tile_rows = pages_per_tile * bs
        live_rows = min(max(math.ceil(prefix / tile_rows), 1) * tile_rows,
                        table_tokens)
        kv = 2 * live_rows * row
    else:
        kv = 3 * 2 * table_tokens * row
    return kv + chunk_kv + q_out


def bench_prefill_chunk(prefixes, block_sizes, *, chunk, num_q_heads,
                        kv_heads, head_dim, iters, time_fused):
    from repro.kernels import ops, ref
    from repro.kernels.paged_decode_attention import auto_pages_per_tile

    gather_fn = jax.jit(ref.paged_prefill_attention_ref)
    rows = []
    rng = np.random.default_rng(0)
    for bs in block_sizes:
        for prefix in prefixes:
            nb = math.ceil((prefix + chunk) / bs)   # table covers the prompt
            N = nb + 8
            q = rng.standard_normal(
                (1, num_q_heads, chunk, head_dim)).astype(np.float32)
            kp = rng.standard_normal(
                (N, kv_heads, bs, head_dim)).astype(np.float32)
            vp = rng.standard_normal(
                (N, kv_heads, bs, head_dim)).astype(np.float32)
            ck = rng.standard_normal(
                (1, kv_heads, chunk, head_dim)).astype(np.float32)
            cv = rng.standard_normal(
                (1, kv_heads, chunk, head_dim)).astype(np.float32)
            bt = rng.permutation(N)[:nb].reshape(1, nb).astype(np.int32)
            starts = np.array([prefix], np.int32)
            valid = np.array([chunk], np.int32)
            args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                    jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(bt),
                    jnp.asarray(starts), jnp.asarray(valid))
            P = auto_pages_per_tile(bs, nb)
            gather_us = _time_call(gather_fn, *args, iters=iters) * 1e6
            fused_us = (_time_call(ops.paged_prefill_attention, *args,
                                   iters=iters) * 1e6 if time_fused else None)
            model = dict(prefix=prefix, table_tokens=nb * bs, bs=bs,
                         chunk=chunk, num_q_heads=num_q_heads,
                         kv_heads=kv_heads, head_dim=head_dim, itemsize=4,
                         pages_per_tile=P)
            g_bytes = modeled_chunk_hbm_bytes(fused=False, **model)
            f_bytes = modeled_chunk_hbm_bytes(fused=True, **model)
            rows.append({
                "prefix": prefix, "block_size": bs, "chunk": chunk,
                "pages_per_tile": P,
                "gather_us": round(gather_us, 1),
                "fused_us": None if fused_us is None else round(fused_us, 1),
                "gather_hbm_bytes": g_bytes,
                "fused_hbm_bytes": f_bytes,
                "hbm_bytes_saved": g_bytes - f_bytes,
                "hbm_ratio": round(g_bytes / f_bytes, 3),
            })
    return rows


def cumulative_prefill(prompt_lens, block_sizes, *, chunk, num_q_heads,
                       kv_heads, head_dim):
    """Whole-prompt totals: per-chunk bytes summed over every chunk of the
    prefill (the gather path re-densifies the FULL table each chunk, which
    is what made chunked prefill quadratic in HBM traffic)."""
    rows = []
    for bs in block_sizes:
        for L in prompt_lens:
            table = math.ceil(L / bs) * bs
            n_chunks = math.ceil(L / chunk)
            g = f = 0
            from repro.kernels.paged_decode_attention import \
                auto_pages_per_tile
            P = auto_pages_per_tile(bs, table // bs)
            for i in range(n_chunks):
                model = dict(prefix=i * chunk, table_tokens=table, bs=bs,
                             chunk=chunk, num_q_heads=num_q_heads,
                             kv_heads=kv_heads, head_dim=head_dim,
                             itemsize=4, pages_per_tile=P)
                g += modeled_chunk_hbm_bytes(fused=False, **model)
                f += modeled_chunk_hbm_bytes(fused=True, **model)
            rows.append({"prompt_len": L, "block_size": bs, "chunk": chunk,
                         "gather_hbm_bytes": g, "fused_hbm_bytes": f,
                         "hbm_ratio": round(g / f, 3)})
    return rows


def bench_decode_tiles(block_sizes, *, kv_tokens, num_q_heads, kv_heads,
                       head_dim, iters):
    """Paged decode wall time: single-page grid steps vs auto multi-page
    tiles (identical HBM traffic — the win is MXU tile occupancy, so TPU
    wall time is the metric; interpret-mode numbers only sanity-check that
    fewer grid steps run)."""
    from repro.kernels import ops
    from repro.kernels.paged_decode_attention import auto_pages_per_tile

    rows = []
    rng = np.random.default_rng(1)
    for bs in block_sizes:
        nb = kv_tokens // bs
        N = nb + 8
        q = rng.standard_normal((1, num_q_heads, head_dim)).astype(np.float32)
        kp = rng.standard_normal((N, kv_heads, bs, head_dim)).astype(np.float32)
        vp = rng.standard_normal((N, kv_heads, bs, head_dim)).astype(np.float32)
        bt = rng.permutation(N)[:nb].reshape(1, nb).astype(np.int32)
        lengths = np.array([kv_tokens - 3], np.int32)
        auto_p = auto_pages_per_tile(bs, nb)
        entry = {"block_size": bs, "kv_tokens": kv_tokens,
                 "auto_pages_per_tile": auto_p}
        for label, P in (("single_page_us", 1), ("multi_page_us", auto_p)):
            us = _time_call(
                lambda *a, P=P: ops.paged_decode_attention(
                    *a, pages_per_tile=P),
                jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(bt), jnp.asarray(lengths), iters=iters) * 1e6
            entry[label] = round(us, 1)
        rows.append(entry)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep (still covers >= 2k prefixes)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()

    if args.smoke:
        prefixes = [256, 2048, 4096]
        block_sizes = [16]
        prompt_lens = [2048, 4096]
        shape = dict(chunk=64, num_q_heads=4, kv_heads=2, head_dim=32)
        iters = args.iters or 3
    else:
        prefixes = [256, 512, 1024, 2048, 4096, 8192]
        block_sizes = [8, 16, 32]
        prompt_lens = [2048, 8192]
        shape = dict(chunk=128, num_q_heads=8, kv_heads=2, head_dim=64)
        iters = args.iters or 5

    on_tpu = jax.default_backend() == "tpu"
    t0 = time.time()
    result = {
        "meta": {
            "backend": jax.default_backend(),
            "pallas_interpret": not on_tpu,
            "shape": shape,
            "note": ("fused wall times run the Pallas kernel in interpret "
                     "mode off-TPU (Python per grid step — not a perf "
                     "number); gather/fused modeled HBM bytes are the "
                     "roofline comparison and hold on any backend"),
        },
        "prefill_chunk": bench_prefill_chunk(
            prefixes, block_sizes, iters=iters, time_fused=True, **shape),
        "prefill_total": cumulative_prefill(prompt_lens, block_sizes, **shape),
        "decode_tiles": bench_decode_tiles(
            block_sizes, kv_tokens=2048 if args.smoke else 4096,
            iters=iters, num_q_heads=shape["num_q_heads"],
            kv_heads=shape["kv_heads"], head_dim=shape["head_dim"]),
    }
    result["meta"]["wall_seconds"] = round(time.time() - t0, 1)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({result['meta']['wall_seconds']}s)")
    for r in result["prefill_chunk"]:
        print(f"prefill bs={r['block_size']:>3} prefix={r['prefix']:>5}: "
              f"gather {r['gather_hbm_bytes']:>12,} B vs fused "
              f"{r['fused_hbm_bytes']:>12,} B  ({r['hbm_ratio']}x)")


if __name__ == "__main__":
    main()
