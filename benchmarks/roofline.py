"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod mesh:

    compute term    = flops_per_device / peak_FLOPs_per_chip
    memory term     = bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw_per_chip

(cost_analysis() is per-device under SPMD, so dividing by per-chip peaks is
equivalent to the global/(chips × peak) formulation for balanced programs.)

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step;
for decode D = tokens_decoded (global_batch), for prefill D = batch·seq.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_BYTES = 16 * 2**30       # v5e HBM per chip

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,        # ONE new token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    """6·N·D for training; forward-only shapes use 2·N·D (D = tokens
    actually processed by the step)."""
    n = rec["model_active_params"]
    d = SHAPE_TOKENS[rec["shape"]]
    factor = 6.0 if rec["shape"] == "train_4k" else 2.0
    return factor * n * d


def analyze(rec: Dict, correct: bool = True) -> Optional[Dict]:
    if not rec.get("applicable", False) or "cost" not in rec:
        return None
    n_chips = rec["n_chips"]
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_accessed_per_device"]
    coll_dev = rec["collectives"]["total_bytes"]  # per-device program
    mf = model_flops(rec)
    hlo_global = flops_dev * n_chips
    # XLA:CPU cost_analysis counts some while-loop bodies ONCE instead of
    # × trip-count (verified empirically; see EXPERIMENTS §Roofline notes).
    # When the analytic 6·N·D exceeds measured HLO flops, the scan was
    # undercounted: correct the compute/memory terms by the ratio.
    undercount = max(1.0, mf / hlo_global) if (hlo_global and correct) else 1.0
    flops_dev_c = flops_dev * undercount
    bytes_dev_c = bytes_dev * undercount
    t_compute = flops_dev_c / PEAK_FLOPS
    t_memory = bytes_dev_c / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "scan_undercount_corrected": undercount > 1.0,
        "useful_flops_ratio": min(mf / (hlo_global * undercount), 1.0)
                              if hlo_global else 0.0,
        "peak_gib_per_device": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "fits_hbm": rec["memory"]["peak_bytes_per_device"] <= HBM_BYTES,
        "collective_breakdown": rec["collectives"]["bytes_by_op"],
        "dropped_shardings": rec.get("dropped_shardings", []),
    }


def load_records(mesh: str = "pod16x16", tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("tag", "") == tag:
            recs.append(r)
    return recs


def roofline_table(mesh: str = "pod16x16", tag: str = "") -> List[Dict]:
    rows = []
    for rec in load_records(mesh, tag):
        a = analyze(rec)
        if a is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh"), "skipped": True,
                         "reason": rec.get("skip_reason", "")})
        else:
            rows.append(a)
    return rows


def format_table(rows: List[Dict]) -> str:
    lines = [f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
             f"{'collect':>10s} {'bound':>9s} {'useful':>7s} {'GiB/dev':>8s} fits"]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']:24s} {r['shape']:12s} {'—':>10s} "
                         f"(skipped: sub-quadratic attention required)")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['compute_s']*1e3:9.2f}ms {r['memory_s']*1e3:9.2f}ms "
            f"{r['collective_s']*1e3:9.2f}ms {r['dominant']:>9s} "
            f"{r['useful_flops_ratio']:6.1%} {r['peak_gib_per_device']:8.2f} "
            f"{'Y' if r['fits_hbm'] else 'OVER'}")
    return "\n".join(lines)


def main() -> List:
    rows = roofline_table()
    print(format_table(rows))
    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "roofline_table.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    done = [r for r in rows if not r.get("skipped")]
    n_fit = sum(1 for r in done if r["fits_hbm"])
    return [("roofline_table", "0",
             f"{len(done)} pairs analyzed, {n_fit} fit 16GiB HBM, "
             f"dominant: {max(set(r['dominant'] for r in done), key=[r['dominant'] for r in done].count)}")]


if __name__ == "__main__":
    main()
