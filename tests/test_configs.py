"""Architecture registry: exact assigned configs + reduced-variant rules."""
import pytest

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_arch, shape_applicable

EXPECTED = {
    # name: (arch_type, layers, d_model, heads, kv, d_ff, vocab)
    "granite-3-2b": ("dense", 40, 2048, 32, 8, 8192, 49155),
    "qwen3-moe-30b-a3b": ("moe", 48, 2048, 32, 4, 768, 151936),
    "h2o-danube-1.8b": ("dense", 24, 2560, 32, 8, 6912, 32000),
    "deepseek-67b": ("dense", 95, 8192, 64, 8, 22016, 102400),
    "zamba2-1.2b": ("hybrid", 38, 2048, 32, 32, 8192, 32000),
    "qwen1.5-32b": ("dense", 64, 5120, 40, 40, 27392, 152064),
    "mamba2-130m": ("ssm", 24, 768, 0, 0, 0, 50280),
    "llava-next-34b": ("vlm", 60, 7168, 56, 8, 20480, 64000),
    "dbrx-132b": ("moe", 40, 6144, 48, 8, 10752, 100352),
    "whisper-medium": ("audio", 24, 1024, 16, 16, 4096, 51865),
}


def test_all_ten_assigned():
    assert set(ARCHITECTURES) == set(EXPECTED)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_config(name):
    t, L, d, h, kv, ff, v = EXPECTED[name]
    c = get_arch(name)
    assert (c.arch_type, c.num_layers, c.d_model, c.num_heads,
            c.num_kv_heads, c.d_ff, c.vocab_size) == (t, L, d, h, kv, ff, v)
    assert c.source, "every config must cite its source"


@pytest.mark.parametrize("name,expected_b", [
    ("granite-3-2b", 2.5), ("qwen3-moe-30b-a3b", 30.5), ("deepseek-67b", 67.4),
    ("dbrx-132b", 131.6), ("mamba2-130m", 0.13), ("whisper-medium", 1.0),
])
def test_param_counts_near_published(name, expected_b):
    got = get_arch(name).param_count() / 1e9
    assert abs(got - expected_b) / expected_b < 0.15, (name, got)


def test_moe_active_params():
    c = get_arch("qwen3-moe-30b-a3b")
    assert c.moe.num_experts == 128 and c.moe.experts_per_token == 8
    active = c.active_param_count() / 1e9
    assert 2.5 < active < 4.5  # "A3B" ≈ 3B active
    d = get_arch("dbrx-132b")
    assert d.moe.num_experts == 16 and d.moe.experts_per_token == 4
    assert 30 < d.active_param_count() / 1e9 < 45  # ~36B active


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_reduced_constraints(name):
    r = get_arch(name).reduced()
    assert r.num_layers <= 2 and r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4
    assert r.arch_type == get_arch(name).arch_type  # same family


def test_long_context_applicability():
    long = [s for s in INPUT_SHAPES if s.name == "long_500k"][0]
    runs = {n for n in ARCHITECTURES if shape_applicable(get_arch(n), long)}
    assert runs == {"mamba2-130m", "zamba2-1.2b", "h2o-danube-1.8b"}
    # everything else runs all other shapes
    for s in INPUT_SHAPES:
        if s.name != "long_500k":
            assert all(shape_applicable(get_arch(n), s) for n in ARCHITECTURES)


def test_padded_vocab_divisible_by_256():
    for c in ARCHITECTURES.values():
        assert c.padded_vocab % 256 == 0
        assert 0 <= c.padded_vocab - c.vocab_size < 256
