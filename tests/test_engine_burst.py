"""Fused multi-step decode dispatch (``EngineConfig.decode_burst``).

The acceptance bar: for every attention backend, driving the engine with
``steps()`` at burst widths 1 / 4 / 16 produces tokens IDENTICAL to the
seed single-step ``step()`` loop — including a mid-burst EOS (finish flag
raised inside the fused loop), a mid-burst KV-pool exhaustion (preemption
+ snapshot resume), and donation on/off.  Also locks the incremental
block-table invariant: ``BlockManager.slot_table()`` always equals the
from-scratch ``_block_table_array()`` rebuild.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig

BACKENDS = ("xla", "pallas", "paged-xla", "paged-pallas")


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_engine(model, params, **kw):
    cfg = EngineConfig(**{"max_slots": 4, "max_seq_len": 64,
                          "prefill_chunk_tokens": 16, "block_size": 8, **kw})
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def _req(prompt, n=8):
    return Request(prompt_tokens=list(prompt), model="m1", slo=1e9,
                   max_new_tokens=n)


def _drive(eng, reqs, max_iters=300):
    """steps() (burst when configured) until every request finishes,
    re-admitting preempted requests as capacity frees up — and assert the
    incremental table matches the from-scratch rebuild each iteration."""
    for _ in range(max_iters):
        eng.steps()
        if eng.cfg.incremental_block_table:
            np.testing.assert_array_equal(eng.block_mgr.slot_table(),
                                          eng._block_table_array())
        for r in reqs:
            if not r.finished() and r.snapshot is not None \
                    and not any(s is r for s in eng.slots):
                if eng.can_admit(r):
                    assert eng.admit(r)
        if all(r.finished() for r in reqs):
            return [r.output_tokens for r in reqs]
    raise AssertionError("requests did not finish")


# ---------------------------------------------------------------------------
# token parity across burst widths x backends (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_burst_token_parity_all_backends(small_model, backend):
    _, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 17, 30, 9)]

    # seed behavior: single-step loop, no donation, rebuilt tables
    base = _mk_engine(model, params, attention_backend=backend,
                      decode_burst=1, donate_buffers=False,
                      incremental_block_table=False)
    base_reqs = [_req(p) for p in prompts]
    for r in base_reqs:
        assert base.admit(r)
    want = _drive(base, base_reqs)
    assert all(len(t) == 8 for t in want)

    for burst in (4, 16):
        eng = _mk_engine(model, params, attention_backend=backend,
                         decode_burst=burst)
        reqs = [_req(p) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        got = _drive(eng, reqs)
        assert got == want, (backend, burst)
        assert eng.block_mgr.used_blocks == 0
        # the fused loop really ran multi-step dispatches: same iteration
        # count, strictly fewer device round-trips than iterations
        assert eng.stats.decode_iterations == base.stats.decode_iterations


def test_burst_with_mixed_admissions_interleaves_prefill(small_model):
    """steps() falls back to single-step while any slot is mid-prefill and
    bursts once prefill drains — tokens identical to the step() loop when
    admissions arrive mid-serve through a pull source."""
    _, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (25, 4, 18)]

    def run(burst):
        eng = _mk_engine(model, params, attention_backend="paged-xla",
                         decode_burst=burst, max_slots=2)
        queue = [_req(p, n=6) for p in prompts]
        reqs = list(queue)
        eng.pull_source = lambda: queue.pop(0) if queue else None
        for _ in range(300):
            eng.steps()
            back = eng.take_pushback()
            if back is not None:
                queue.insert(0, back)
                back._in_flight = False
            if all(r.finished() for r in reqs):
                return [r.output_tokens for r in reqs]
        raise AssertionError("did not finish")

    assert run(4) == run(1)


# ---------------------------------------------------------------------------
# mid-burst EOS / mid-burst OOM
# ---------------------------------------------------------------------------

def test_mid_burst_eos_finish(small_model):
    """An EOS raised INSIDE a burst must retire the slot at the same token
    as the single-step loop (the remaining fused iterations mask it)."""
    _, model, params = small_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (5, 12)]

    probe = _mk_engine(model, params, attention_backend="xla")
    probe_reqs = [_req(p, n=16) for p in prompts]
    for r in probe_reqs:
        assert probe.admit(r)
    _drive(probe, probe_reqs)
    # an eos that fires mid-stream (not on the first token, inside the
    # first burst of 8) for at least one request
    eos = probe_reqs[0].output_tokens[2]

    def run(backend, burst):
        eng = _mk_engine(model, params, attention_backend=backend,
                         decode_burst=burst, eos_token=eos)
        reqs = [_req(p, n=16) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        return _drive(eng, reqs)

    for backend in ("xla", "paged-xla"):
        want = run(backend, 1)
        assert any(t[-1] == eos and len(t) < 16 for t in want)  # fired early
        assert run(backend, 8) == want


def test_mid_burst_oom_preempts_and_resumes(small_model):
    """A burst that would overrun the block pool shrinks / falls back to the
    single-step preemption path; the preempted request resumes from its
    snapshot and the final tokens match an uncontended run."""
    _, model, params = small_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, size=12).tolist() for _ in range(2)]

    # uncontended reference: big pool, no preemption possible
    ref_eng = _mk_engine(model, params, attention_backend="paged-xla",
                         decode_burst=4)
    ref_reqs = [_req(p, n=24) for p in prompts]
    for r in ref_reqs:
        assert ref_eng.admit(r)
    want = _drive(ref_eng, ref_reqs)
    assert ref_eng.stats.preemptions == 0

    # starved pool: 8 blocks * 8 = 64 tokens for 2 requests needing
    # (12 + 24 + 1) tokens each -> decode must exhaust the pool mid-serve
    eng = _mk_engine(model, params, attention_backend="paged-xla",
                     decode_burst=4, kv_blocks=8, max_seq_len=40)
    reqs = [_req(p, n=24) for p in prompts]
    for r in reqs:
        assert eng.admit(r)
    got = _drive(eng, reqs)
    assert eng.stats.preemptions >= 1          # OOM fired mid-serve
    assert eng.stats.resumes >= 1              # ...and resumed from snapshot
    assert got == want


# ---------------------------------------------------------------------------
# donation + incremental table
# ---------------------------------------------------------------------------

def test_donation_toggle_token_parity(small_model):
    """donate_buffers only changes buffer lifetimes, never tokens — and the
    donated engine's old cache buffers really are consumed."""
    _, model, params = small_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 20)]

    outs = {}
    for donate in (True, False):
        eng = _mk_engine(model, params, attention_backend="paged-xla",
                         donate_buffers=donate, max_slots=2)
        reqs = [_req(p, n=6) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        if donate:
            cache_before = eng.cache
        _drive(eng, reqs)
        if donate:
            leaf = jax.tree.leaves(cache_before)[0]
            with pytest.raises((RuntimeError, ValueError)):
                np.asarray(leaf)  # donated into the first dispatch
        outs[donate] = [r.output_tokens for r in reqs]
    assert outs[True] == outs[False]


def test_quant_burst_parity(small_model):
    """int8 KV pools burst token-identically (fused-dequant kernels inside
    the lax loop)."""
    cfg = dataclasses.replace(
        ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64),
        kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (5, 21)]

    def run(burst):
        eng = _mk_engine(model, params, attention_backend="paged-xla",
                         decode_burst=burst, max_slots=2)
        reqs = [_req(p, n=5) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        return _drive(eng, reqs)

    assert run(4) == run(1)


def test_block_table_version_only_bumps_on_change(small_model):
    """The device block-table upload is refreshed only when the manager's
    table actually changed: a decode burst that stays inside already-
    reserved blocks must reuse the same device array."""
    _, model, params = small_model
    eng = _mk_engine(model, params, attention_backend="paged-xla",
                     decode_burst=1)
    r = _req(list(range(3)), n=12)
    assert eng.admit(r)
    while eng.prefilling_slots():
        eng.step()
    bt1 = eng._device_block_table()
    bt2 = eng._device_block_table()
    assert bt1 is bt2                       # no mutation -> cached upload
    v = eng.block_mgr.table_version
    eng.step()                              # append_token may extend a block
    if eng.block_mgr.table_version == v:
        assert eng._device_block_table() is bt1
    else:
        assert eng._device_block_table() is not bt1
