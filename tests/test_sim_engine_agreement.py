"""Simulator/engine agreement: the same deterministic QLM scenario, driven
once through ``ClusterSimulator`` and once through the real JAX engine with
the QLM controller + LSO agent, must produce the same admission / eviction /
swap counts (the simulator is only trustworthy for paper-scale experiments
if its LSO semantics mirror the engine's)."""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.sim.simulator import ClusterSimulator

MODELS = ("granite-3-2b", "h2o-danube-1.8b")


@pytest.fixture(scope="module")
def registry():
    key = jax.random.key(0)
    reg = {}
    for name in MODELS:
        cfg = ARCHITECTURES[name].reduced(num_layers=2, d_model=128)
        model = build_model(cfg)
        reg[name] = (model, model.init(key))
    return reg


def _hw():
    return HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                           inefficiency=1.2, token_capacity=512,
                           swap_time=0.2, model_max_tokens=64)


def _slow_hw():
    """Profile slow enough that a queued interactive group's RWT-estimated
    completion busts its 20 s TTFT SLO, forcing the violation-triggered
    reorder (and thus the head-change eviction) on both stacks."""
    return HardwareProfile(prefill_time=0.05, decode_per_token=0.6,
                           inefficiency=1.2, token_capacity=80,
                           swap_time=0.2, model_max_tokens=8)


def _mk_reqs(now=0.0):
    """4 + 4 requests over two models, all at t=now: two request groups."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        r = make_request(rng.integers(0, 100, size=6).tolist(),
                         MODELS[i % 2], "batch1", arrival_time=now,
                         max_new_tokens=3)
        r.true_output_tokens = 3
        reqs.append(r)
    return reqs


def _run_engine(registry, reqs, submit_late=None, max_slots=4, hw=_hw,
                decode_burst=1):
    names = list(MODELS)
    m0, p0 = registry[names[0]]
    eng = ContinuousBatchingEngine(
        m0, p0, EngineConfig(max_slots=max_slots, max_seq_len=64,
                             decode_burst=decode_burst),
        model_name=names[0])
    vq = VirtualQueue(0)
    agent = QLMAgent(eng, vq, registry)
    info = InstanceInfo(0, {n: hw() for n in names}, eng.model_name, vq)
    controller = QLMController([info], QLMConfig(avg_batch_size=max_slots,
                                                 reschedule_cooldown=0.0))
    now = time.monotonic()
    for r in reqs:
        controller.submit(r, now)
    for it in range(400):
        info.current_model = eng.model_name
        agent.run_iteration()
        if submit_late is not None and it == submit_late[0]:
            for r in submit_late[1]:
                controller.submit(r, time.monotonic())
        late = submit_late[1] if submit_late else []
        if all(r.finished() for r in list(reqs) + list(late)):
            break
    return eng, controller


def _run_sim(reqs, max_batch=4, chunked=False, hw=_hw):
    profs = [{n: hw() for n in MODELS}]
    kw = {"traits_override": {"prefill_chunk_tokens": 16}} if chunked else {}
    sim = ClusterSimulator(profs, "qlm", max_batch_requests=max_batch, **kw)
    metrics = sim.run(reqs)
    return sim, metrics


def test_two_group_swap_and_admission_counts_agree(registry):
    reqs_e = _mk_reqs(now=time.monotonic())
    eng, _ = _run_engine(registry, reqs_e)
    assert all(r.finished() for r in reqs_e)

    reqs_s = _mk_reqs(now=0.0)
    sim, metrics = _run_sim(reqs_s)
    assert metrics["completed"] == float(len(reqs_s))

    # admissions: every request served exactly once on both sides
    assert len(eng.completed) == int(metrics["completed"]) == 8
    # evictions: group-ordered service drains each group before the head
    # changes — no HOL eviction on either side
    assert eng.stats.evictions == metrics["evictions"] == 0
    # swaps: the sim counts the cold model load, the engine starts loaded
    assert metrics["swaps"] - 1 == eng.stats.model_swaps
    # both served two model segments (group-level swap amortization)
    assert eng.stats.model_swaps == 1


def test_head_change_eviction_counts_agree(registry):
    """Interactive group jumping the head evicts EXACTLY one running batch
    request on both sides (evict until the head request is admittable)."""
    def mk_batch(now):
        out = []
        for _ in range(2):
            r = make_request(list(range(8)), MODELS[0], "batch2",
                             arrival_time=now, max_new_tokens=30)
            r.true_output_tokens = 30
            out.append(r)
        return out

    def mk_inter(now):
        r = make_request(list(range(8)), MODELS[0], "interactive",
                         arrival_time=now, max_new_tokens=2)
        r.true_output_tokens = 2
        return r

    # --- real engine: 2 slots, interactive submitted mid-run -------------
    now = time.monotonic()
    batch_e = mk_batch(now)
    inter_e = mk_inter(now)
    eng, _ = _run_engine(registry, batch_e, submit_late=(3, [inter_e]),
                         max_slots=2, hw=_slow_hw)
    assert inter_e.finished() and all(r.finished() for r in batch_e)

    # --- simulator: same shape, interactive arrives mid-drain ------------
    batch_s = mk_batch(0.0)
    inter_s = mk_inter(0.1)
    sim, metrics = _run_sim(batch_s + [inter_s], max_batch=2, hw=_slow_hw)
    assert metrics["completed"] == 3.0

    assert eng.stats.evictions == 1
    assert int(metrics["evictions"]) == 1
    assert eng.stats.evictions == int(metrics["evictions"])
    # the evicted batch request resumed and completed on both sides
    assert all(r.finished() for r in batch_e) and all(r.finished() for r in batch_s)


def test_swa_chunk_quantum_counts_agree(registry):
    """The engine clamps its chunk quantum to a model's sliding window
    (engine._chunk_quantum); with HardwareProfile.sliding_window the
    simulator and the RWT prefill term charge the SAME chunk counts for
    SWA models served with chunk > window."""
    name = "h2o-danube-1.8b"          # reduced() keeps sliding_window=64
    model, params = registry[name]
    assert model.cfg.sliding_window == 64
    eng = ContinuousBatchingEngine(
        model, params,
        EngineConfig(max_slots=1, max_seq_len=256, prefill_chunk_tokens=128),
        model_name=name)
    assert eng._chunk_quantum() == 64  # window-clamped, not 128
    prompt = list(range(100))
    r = make_request(prompt, name, "batch1", arrival_time=0.0,
                     max_new_tokens=2)
    assert eng.admit(r)
    for _ in range(20):
        eng.step()
        if r.finished():
            break
    assert r.finished()
    assert eng.stats.prefill_chunks == 2          # ceil(100 / 64)

    hw = HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                         inefficiency=1.2, token_capacity=512, swap_time=0.2,
                         model_max_tokens=64, sliding_window=64)
    sim = ClusterSimulator([{name: hw}], "qlm",
                           traits_override={"prefill_chunk_tokens": 128})
    r_s = make_request(prompt, name, "batch1", arrival_time=0.0,
                       max_new_tokens=2)
    r_s.true_output_tokens = 2
    sim.run([r_s])
    assert sim.instances[0].stats.prefill_rounds == 2   # was 1 pre-clamp
    # the effective quantum itself agrees engine <-> profile (the sim put
    # the policy's 128-token quantum on its own profile copy; mirror that)
    import dataclasses
    hw_chunked = dataclasses.replace(hw, prefill_chunk_tokens=128)
    assert hw_chunked.chunk_quantum() == eng._chunk_quantum() == 64
    # and the RWT prefill term charges ceil(100/64) = 2 interleaved decodes
    assert hw_chunked.prefill_seconds(100) == pytest.approx(
        hw.prefill_seconds(100) + 2 * hw.decode_per_token)


def test_burst_mode_counts_agree_and_dispatch_amortizes(registry):
    """Burst-aware accounting (ROADMAP follow-on): the engine running
    ``decode_burst=4`` still produces the same admission/eviction/swap
    counts as the simulator, and threading the burst width into
    ``HardwareProfile`` makes the simulator charge the per-dispatch host
    overhead once per burst instead of once per iteration."""
    reqs_e = _mk_reqs(now=time.monotonic())
    eng, _ = _run_engine(registry, reqs_e, decode_burst=4)
    assert all(r.finished() for r in reqs_e)

    def hw_burst(burst):
        def mk():
            return HardwareProfile(
                prefill_time=0.05, decode_per_token=0.02, inefficiency=1.2,
                token_capacity=512, swap_time=0.2, model_max_tokens=64,
                decode_burst=burst, dispatch_overhead=0.01)
        return mk

    sim1, m1 = _run_sim(_mk_reqs(), hw=hw_burst(1))
    sim4, m4 = _run_sim(_mk_reqs(), hw=hw_burst(4))
    # LSO counts: burst changes TIMING only, on both stacks
    assert len(eng.completed) == int(m4["completed"]) == 8
    assert eng.stats.evictions == int(m4["evictions"]) == 0
    assert m4["swaps"] - 1 == eng.stats.model_swaps == 1
    for key in ("completed", "evictions", "swaps", "preemptions"):
        assert m1[key] == m4[key], key
    # amortization: the same workload burns strictly less busy time when
    # the dispatch overhead is charged once per 4-iteration burst
    busy1 = sum(i.stats.busy_time for i in sim1.instances)
    busy4 = sum(i.stats.busy_time for i in sim4.instances)
    assert busy4 < busy1
    # the per-iteration charge itself follows d + overhead / burst
    assert hw_burst(4)().decode_seconds() == pytest.approx(0.02 + 0.01 / 4)
    assert hw_burst(1)().decode_seconds() == pytest.approx(0.03)
    # ... and chunk-interleaved iterations dispatch single-step
    assert hw_burst(4)().decode_seconds(1) == pytest.approx(0.03)


def test_calibration_threads_burst_width(registry):
    """calibrate_from_engine carries the engine's decode_burst into the
    profile so simulator experiments charge the measured operating mode."""
    from repro.sim.profiles import calibrate_from_engine
    name = MODELS[0]
    model, params = registry[name]
    eng = ContinuousBatchingEngine(
        model, params, EngineConfig(max_slots=2, max_seq_len=64,
                                    decode_burst=4),
        model_name=name)
    hw = calibrate_from_engine(eng, token_capacity=512,
                               dispatch_overhead=0.005)
    assert hw.decode_burst == 4
    assert hw.decode_seconds() == pytest.approx(
        hw.decode_per_token + 0.005 / 4)


def test_effective_prefill_tokens_reflect_cache_hits():
    """Shared-prefix cache hits shrink BOTH the RWT prefill term and the
    simulator's prefill work/KV (Request.prefix_shared_tokens)."""
    hw = HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                         inefficiency=1.2, token_capacity=512, swap_time=0.2,
                         model_max_tokens=64, prefill_chunk_tokens=16)
    # RWT: rate AND interleaved chunk count scale with the effective tokens
    assert hw.prefill_seconds(64, effective_prompt_tokens=16) \
        == pytest.approx(0.05 * 16 / 1024 + 1 * 0.02)
    assert hw.prefill_seconds(64, effective_prompt_tokens=16) \
        < hw.prefill_seconds(64)
    from repro.core.rwt_estimator import RWTEstimator, WorkloadProfile
    est = RWTEstimator()
    wl = WorkloadProfile(64.0, 1.0, 8.0, 1.0)
    full = est.request_completion(0, wl, hw, prompt_tokens=64.0)
    eff = est.request_completion(0, wl, hw, prompt_tokens=64.0,
                                 effective_prompt_tokens=16.0)
    assert eff.mean < full.mean

    # simulator: prefill rounds follow the UNSHARED remainder only
    def run_one(shared):
        r = make_request(list(range(100)), MODELS[0], "batch1",
                         arrival_time=0.0, max_new_tokens=2)
        r.true_output_tokens = 2
        r.prefix_shared_tokens = shared
        sim = ClusterSimulator([{MODELS[0]: hw}], "qlm",
                               traits_override={"prefill_chunk_tokens": 16})
        sim.run([r])
        return sim.instances[0].stats

    assert run_one(0).prefill_rounds == 7      # ceil(100 / 16)
    assert run_one(64).prefill_rounds == 3     # ceil((100 - 64) / 16)


def test_chunked_sim_same_counts_as_lump(registry):
    """The chunk-interleaved simulator accounting changes TIMING only:
    admission/eviction/swap counts of the two-group scenario match the
    lump-prefill simulator and therefore the engine."""
    lump_sim, lump = _run_sim(_mk_reqs())
    chunk_sim, chunk = _run_sim(_mk_reqs(), chunked=True)
    for key in ("completed", "evictions", "swaps", "preemptions"):
        assert lump[key] == chunk[key], key
