"""Dry-run support: input_specs shapes, HLO collective parsing, workload
generators (unit-level — the 512-device sweep itself runs via
``python -m repro.launch.dryrun``)."""
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_arch, get_shape
from repro.launch.hlo_analysis import collective_stats
from repro.models.model_factory import batch_struct


def test_batch_struct_train_shapes():
    cfg = get_arch("granite-3-2b")
    b = batch_struct(cfg, 256, 4096, "train")
    assert b["tokens"].shape == (256, 4097)


def test_batch_struct_vlm_includes_patches():
    cfg = get_arch("llava-next-34b")
    b = batch_struct(cfg, 32, 32768, "prefill")
    assert "patch_embeds" in b
    assert b["patch_embeds"].shape == (32, 2880, 7168)
    assert b["tokens"].shape[1] + 2880 == 32768


def test_batch_struct_audio_includes_frames():
    cfg = get_arch("whisper-medium")
    b = batch_struct(cfg, 256, 4096, "train")
    assert b["frame_embeds"].shape == (256, 1500, 1024)


def test_batch_struct_decode():
    cfg = get_arch("deepseek-67b")
    b = batch_struct(cfg, 128, 32768, "decode")
    assert b["tokens"].shape == (128,)
    assert b["lengths"].shape == (128,)


def test_assigned_shapes_exact():
    names = {(s.name, s.seq_len, s.global_batch, s.kind) for s in INPUT_SHAPES}
    assert names == {
        ("train_4k", 4096, 256, "train"),
        ("prefill_32k", 32768, 32, "prefill"),
        ("decode_32k", 32768, 128, "decode"),
        ("long_500k", 524288, 1, "decode"),
    }


HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x), replica_groups={...}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %ars = f32[2,8]{1,0} all-reduce-start(f32[2,8]{1,0} %z), to_apply=%sum
  %ard = f32[2,8]{1,0} all-reduce-done(f32[2,8]{1,0} %ars)
  %rs = bf16[2,2048]{1,0} reduce-scatter(bf16[2,32768]{1,0} %w), dimensions={1}
  %a2a = f32[4,64]{1,0} all-to-all(f32[4,64]{1,0} %v), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %u), source_target_pairs={{0,1}}
}
"""


def test_collective_parsing_counts_and_bytes():
    st = collective_stats(HLO_SAMPLE)
    assert st.count_by_op["all-gather"] == 1
    assert st.count_by_op["all-reduce"] == 2          # plain + -start
    assert st.count_by_op["reduce-scatter"] == 1
    assert st.count_by_op["all-to-all"] == 1
    assert st.count_by_op["collective-permute"] == 1
    assert st.bytes_by_op["all-gather"] == 16 * 4096 * 2
    assert st.bytes_by_op["all-reduce"] == 256 * 4 + 2 * 8 * 4
    assert st.bytes_by_op["reduce-scatter"] == 2 * 2048 * 2


def test_workload_generators():
    from repro.data.workload import workload_a, workload_b, workload_c
    wa = workload_a(arrival_rate=10, n_requests=200, seed=0)
    assert len(wa) == 200
    arr = [r.arrival_time for r in wa]
    assert arr == sorted(arr)
    assert {r.slo_class for r in wa} == {"interactive", "batch1", "batch2"}

    wb = workload_b(arrival_rate=10, n_requests=200, seed=0)
    assert len({r.model for r in wb}) == 5  # multi-model

    wc = workload_c(arrival_rate=10, n_requests=400, seed=0, mega_fraction=0.2)
    totals = [r.prompt_len + r.max_new_tokens for r in wc]
    mega = [t for t in totals if t >= 2800]
    assert len(mega) > 20  # mega prompts present (3k-4k band)
    assert max(totals) <= 4200


def test_sharegpt_distribution_moments():
    from repro.data.sharegpt_synth import sample_lengths
    rng = np.random.default_rng(0)
    ins, outs = sample_lengths(rng, 20_000)
    # Fig. 8-like: output median much larger than input median, heavy tails
    assert 25 < np.median(ins) < 90
    assert 100 < np.median(outs) < 300
    assert ins.max() <= 2048 and outs.max() <= 2048
