"""Sharding-rule unit tests (no devices needed: spec_for only reads
mesh.shape)."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec

from repro.distributed.sharding import DEFAULT_RULES, ShardingRules

MESH = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})
MESH_1POD = SimpleNamespace(shape={"data": 16, "model": 16})


def test_right_alignment_pads_stacked_dims():
    r = ShardingRules.default()
    # (layers, d, ff) with 2-entry logical axes -> layers replicated
    spec = r.spec_for(MESH_1POD, (40, 2048, 8192), ("embed", "ff"))
    assert spec == PartitionSpec(None, None, "model")


def test_divisibility_guard_drops_axis():
    r = ShardingRules.default()
    spec = r.spec_for(MESH_1POD, (49155, 2048), ("vocab", "embed"), "embed")
    assert spec == PartitionSpec(None, None)
    assert any("vocab" in d for d in r.dropped)
    # padded vocab shards fine
    r2 = ShardingRules.default()
    assert r2.spec_for(MESH_1POD, (49408, 2048), ("vocab", "embed")) == \
        PartitionSpec("model", None)
    assert not r2.dropped


def test_batch_uses_pod_and_data():
    r = ShardingRules.default()
    spec = r.spec_for(MESH, (256, 4097), ("batch", None))
    assert spec == PartitionSpec(("pod", "data"), None)
    # single-pod mesh: "pod" filtered out
    spec = r.spec_for(MESH_1POD, (256, 4097), ("batch", None))
    assert spec == PartitionSpec("data", None)


def test_batch_one_replicates():
    r = ShardingRules.default()
    spec = r.spec_for(MESH, (1,), ("batch",))
    assert spec == PartitionSpec(None)


def test_no_duplicate_mesh_axes():
    r = ShardingRules({"a": "model", "b": "model"})
    spec = r.spec_for(MESH_1POD, (32, 32), ("a", "b"))
    flat = [x for x in spec if x is not None]
    assert flat.count("model") == 1


def test_overrides():
    r = ShardingRules.default({"embed": "data"})
    spec = r.spec_for(MESH_1POD, (4096, 8192), ("embed", "ff"))
    assert spec == PartitionSpec("data", "model")


def test_default_rules_cover_all_logical_axes_used_by_models():
    import jax
    from repro.configs import ARCHITECTURES
    from repro.models import build_model
    used = set()
    for cfg in ARCHITECTURES.values():
        m = build_model(cfg.reduced())
        for t in (m.param_axes(), m.cache_axes()):
            for ax in jax.tree_util.tree_leaves(t, is_leaf=lambda x: isinstance(x, tuple)):
                used.update(a for a in ax if a is not None)
    missing = used - set(DEFAULT_RULES)
    assert not missing, missing
