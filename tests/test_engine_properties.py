"""Property-based stress test: random interleavings of engine operations
must preserve the block-accounting and slot invariants."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig

_cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
_model = build_model(_cfg)
_params = _model.init(jax.random.key(0))


def _invariants(eng: ContinuousBatchingEngine):
    bm = eng.block_mgr
    assert bm.free_blocks + bm.used_blocks == bm.num_blocks
    active = [r for r in eng.slots if r is not None]
    # every active slot has an allocation; every allocation has a slot
    for r in active:
        assert bm.has(r.req_id)
    assert len(active) == len(bm._seqs)
    # lengths nonzero iff slot active; mid-prefill slots track chunk progress
    for i, r in enumerate(eng.slots):
        if r is None:
            assert eng.lengths[i] == 0
            assert eng.prefill_pos[i] == 0
        elif eng.prefill_pos[i] < r.prompt_len:
            assert eng.lengths[i] == eng.prefill_pos[i]
        else:
            assert eng.lengths[i] >= r.prompt_len


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=st.lists(st.sampled_from(["admit", "step", "evict", "step", "step"]),
                    min_size=1, max_size=25),
       seed=st.integers(0, 2**16))
def test_engine_invariants_under_random_ops(ops, seed):
    rng = np.random.default_rng(seed)
    eng = ContinuousBatchingEngine(
        _model, _params, EngineConfig(max_slots=3, max_seq_len=48,
                                      kv_blocks=12, block_size=4))
    live = []
    for op in ops:
        if op == "admit":
            r = Request(prompt_tokens=rng.integers(0, 64, size=int(rng.integers(2, 8))).tolist(),
                        model="m", slo=1e9, max_new_tokens=int(rng.integers(2, 10)))
            if eng.can_admit(r):
                eng.admit(r)
                live.append(r)
        elif op == "evict" and eng.active_slots():
            slot = int(rng.choice(eng.active_slots()))
            eng.evict_slot(slot)
        else:
            eng.step()
        _invariants(eng)
    # drain: everything admittable finishes eventually
    for _ in range(200):
        if eng.num_active() == 0:
            break
        eng.step()
        _invariants(eng)
    assert eng.block_mgr.used_blocks == 0 or eng.num_active() > 0
