"""Request-group formation (paper §4, Algorithm 1)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.core.request import make_request
from repro.core.request_group import (classify_into_groups,
                                      create_request_groups)


def _reqs(n, models=("m1",), classes=("interactive", "batch1", "batch2"),
          seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(make_request(
            prompt_tokens=list(range(int(rng.integers(4, 200)))),
            model=str(rng.choice(models)),
            slo_class=str(rng.choice(classes)),
            arrival_time=float(i),
            max_new_tokens=int(rng.integers(16, 400))))
    return out


def test_groups_are_model_pure():
    reqs = _reqs(200, models=("m1", "m2", "m3"))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=2)
    for g in groups:
        assert all(r.model == g.model for r in g.requests)


def test_split_respects_max_size():
    """Algorithm 1 lines 2–7: no group exceeds avg_batch_size × δ."""
    reqs = _reqs(500, classes=("batch1",))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=2)
    for g in groups:
        assert g.size() <= 32


def test_every_request_in_exactly_one_group():
    reqs = _reqs(300, models=("m1", "m2"))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=4)
    seen = [r.req_id for g in groups for r in g.requests]
    assert sorted(seen) == sorted(r.req_id for r in reqs)


def test_fcfs_within_group():
    reqs = _reqs(100)
    groups = create_request_groups(reqs, avg_batch_size=8, delta=2)
    for g in groups:
        arrivals = [r.arrival_time for r in g.requests]
        assert arrivals == sorted(arrivals)


def test_slo_classes_tend_to_separate():
    """Clustering on (log SLO, lengths) should not mix 20 s interactive with
    1 h batch in the same group (3 decades apart in feature space)."""
    reqs = _reqs(200, classes=("interactive", "batch2"))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=4)
    mixed = sum(1 for g in groups
                if len({r.slo_class for r in g.requests}) > 1)
    assert mixed <= len(groups) // 4


def test_classify_attaches_to_compatible_group():
    reqs = _reqs(50, models=("m1",), classes=("batch1",))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=4)
    r = make_request(list(range(50)), "m1", "batch1", arrival_time=99.0)
    g = classify_into_groups(r, groups, max_group=64)
    assert g is not None and r in g.requests
    r2 = make_request(list(range(50)), "OTHER", "batch1", arrival_time=99.0)
    assert classify_into_groups(r2, groups, max_group=64) is None


def test_group_cursor_done_semantics():
    reqs = _reqs(10, classes=("batch1",))
    groups = create_request_groups(reqs, avg_batch_size=16, delta=4)
    g = groups[0]
    assert not g.done()
    for r in g.requests:
        r.completion_time = 1.0
        r.first_token_time = 0.5
    assert g.done()
    assert g.next_pending() is None


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 120), batch=st.integers(1, 32),
       delta=st.floats(1.0, 8.0), seed=st.integers(0, 999))
def test_algorithm1_properties(n, batch, delta, seed):
    reqs = _reqs(n, models=("m1", "m2"), seed=seed)
    groups = create_request_groups(reqs, avg_batch_size=batch, delta=delta,
                                   seed=seed)
    max_group = max(1, int(batch * delta))
    assert all(g.size() <= max_group for g in groups)
    assert sum(g.size() for g in groups) == n
    assert all(len({r.model for r in g.requests}) == 1 for g in groups)
