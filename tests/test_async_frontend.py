"""Async serving front end (serving.frontend) over real JAX engines:
backpressure watermarks, deadline expiry, mid-decode cancellation (KV
release), multi-turn session prefix reuse, clean shutdown, and the
overload comparison against the synchronous driver.

Stdlib asyncio only (no pytest-asyncio): each test drives its own
``asyncio.run``.
"""
import argparse
import asyncio
import time

import jax
import numpy as np
import pytest

# Persistent XLA compilation cache: every runner in this module rebuilds
# engines (per-instance jit caches), so without this the overload
# comparison measures compilation stalls, not scheduling.
jax.config.update("jax_compilation_cache_dir", "/tmp/jax-xla-cache-tests")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)

from repro.configs import ARCHITECTURES
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.data.workload import Session
from repro.models import build_model
from repro.serving import (AsyncServer, ContinuousBatchingEngine,
                           EngineConfig, FrontendConfig, run_session)

ARCH = "granite-3-2b"


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHITECTURES[ARCH].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def _hw():
    return HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                           inefficiency=1.2, token_capacity=512,
                           swap_time=0.2, model_max_tokens=64)


def _stack(model, params, *, slots=4, max_seq_len=128, backend="paged-xla",
           block_size=8, kv_blocks=None, fcfg=None):
    ecfg = EngineConfig(max_slots=slots, max_seq_len=max_seq_len,
                        block_size=block_size, kv_blocks=kv_blocks,
                        attention_backend=backend, prefix_sharing=True)
    eng = ContinuousBatchingEngine(model, params, ecfg, model_name=ARCH)
    vq = VirtualQueue(0)
    agent = QLMAgent(eng, vq, {ARCH: (model, params)})
    info = InstanceInfo(0, {ARCH: _hw()}, eng.model_name, vq)
    controller = QLMController([info], QLMConfig(avg_batch_size=slots,
                                                 reschedule_cooldown=0.5))
    server = AsyncServer(controller, [agent], fcfg or FrontendConfig())
    return eng, controller, server


def _req(n_prompt=10, n_new=8, slo_class="interactive", seed=0):
    rng = np.random.default_rng(seed)
    return make_request(rng.integers(0, 100, size=n_prompt).tolist(), ARCH,
                        slo_class, arrival_time=time.monotonic(),
                        max_new_tokens=n_new)


# ---------------------------------------------------------------------------
# ingress: watermarks + hard cap (no event loop needed)
# ---------------------------------------------------------------------------

class _StubEngine:
    model_name = ARCH

    def cancel_request(self, req):
        return False

    def num_active(self):
        return 0


class _StubAgent:
    engine = _StubEngine()

    def run_iteration(self):
        pass


def test_backpressure_watermarks_and_hard_cap():
    inst = InstanceInfo(0, {ARCH: _hw()}, ARCH, VirtualQueue(0))
    controller = QLMController(
        [inst], QLMConfig(avg_batch_size=4, reschedule_on_arrival=False))
    cfg = FrontendConfig(queue_depth=8, high_watermark=4, low_watermark=2)
    srv = AsyncServer(controller, [_StubAgent()], cfg)

    async def go():
        batch = [await srv.submit(_req(slo_class="batch1", seed=i))
                 for i in range(4)]
        assert all(s.status == "queued" for s in batch)
        assert not srv._backpressure
        # depth hit the high watermark: batch arrivals shed at the door
        s = await srv.submit(_req(slo_class="batch2", seed=9))
        assert s.status == "rejected" and srv._backpressure
        assert srv.stats.rejected_backpressure == 1
        assert s.request.completion_time is not None   # accounted, finished
        # interactive keeps flowing until the hard cap
        inter = [await srv.submit(_req(seed=20 + i)) for i in range(4)]
        assert all(s.status == "queued" for s in inter)
        assert srv.queue_depth() == 8
        over = await srv.submit(_req(seed=40))
        assert over.status == "rejected"
        assert srv.stats.rejected_full == 1            # even interactive
        # service drains the queue below the low watermark -> released
        now = time.monotonic()
        for s in batch + inter[:2]:
            s.request.first_token_time = now
        ok = await srv.submit(_req(slo_class="batch1", seed=50))
        assert ok.status == "queued" and not srv._backpressure
        assert srv.stats.backpressure_engagements == 1

    asyncio.run(go())
    # rejected requests count as attainment misses
    assert controller.slo_attainment() < 1.0
    assert len(controller.rejected) == 2


def test_rejected_stream_terminates_immediately():
    inst = InstanceInfo(0, {ARCH: _hw()}, ARCH, VirtualQueue(0))
    controller = QLMController(
        [inst], QLMConfig(reschedule_on_arrival=False))
    srv = AsyncServer(controller, [_StubAgent()],
                      FrontendConfig(queue_depth=1))

    async def go():
        await srv.submit(_req(seed=0))
        s = await srv.submit(_req(seed=1))
        assert s.status == "rejected"
        assert await s.drain() == []                   # terminates, no hang
        # unservable model: 400-style recorded rejection, never an
        # exception out of the serve path
        bad = await srv.submit(make_request([1, 2], "no-such-model",
                                            "batch1",
                                            arrival_time=time.monotonic()))
        assert bad.status == "rejected"
        assert srv.stats.rejected_unservable == 1
        assert await bad.drain() == []

    asyncio.run(go())


# ---------------------------------------------------------------------------
# cancellation frees KV mid-decode
# ---------------------------------------------------------------------------

def test_cancellation_mid_decode_frees_kv_blocks(tiny):
    model, params = tiny
    eng, controller, server = _stack(model, params, slots=2)
    free0 = eng.block_mgr.free_blocks
    assert free0 == eng.block_mgr.num_blocks

    async def go():
        async with server:
            victim = _req(n_prompt=12, n_new=64, seed=1)
            keeper = _req(n_prompt=12, n_new=6, seed=2)
            vs = await server.submit(victim)
            ks = await server.submit(keeper)
            got = []
            async for tok in vs:
                got.append(tok)
                if len(got) == 3:
                    vs.cancel()                        # mid-decode
                    break
            await ks.drain()
            await server.drain()
            assert vs.status == "cancelled"
            return got

    got = asyncio.run(go())
    assert len(got) == 3
    assert eng.stats.cancellations == 1
    # the pool is back to its initial free count: nothing leaked
    assert eng.block_mgr.free_blocks == free0
    assert eng.block_mgr.used_blocks == 0 and eng.num_active() == 0
    # cancellation after first token is NOT an attainment miss
    assert controller.slo_attainment(time.monotonic()) == 1.0


# ---------------------------------------------------------------------------
# deadline expiry: never dispatched
# ---------------------------------------------------------------------------

def test_deadline_expired_request_never_dispatches(tiny):
    model, params = tiny
    # shedding off: otherwise the front end evicts the hog and SERVES the
    # doomed request — this test isolates queue-expiry itself
    eng, controller, server = _stack(model, params, slots=1,
                                     fcfg=FrontendConfig(shed_policy="off"))

    async def go():
        async with server:
            hog = _req(n_prompt=10, n_new=48, slo_class="batch1", seed=3)
            hs = await server.submit(hog)
            doomed = _req(n_prompt=10, n_new=8, seed=4)
            ds = await server.submit(doomed)
            assert ds.status == "queued"
            # force the deadline into the past while doomed is still queued
            # (no await between submit returning and this line, so the
            # server loop cannot have dispatched it): how long the hog
            # holds the slot is machine-dependent, a wall-clock slo races.
            # Backdate the arrival rather than zeroing the slo — the slo
            # feeds the group's min-slo invariant at classification time
            # and must stay immutable after admission (qlint invariants)
            doomed.arrival_time -= 1e9
            await ds.drain()
            assert ds.status == "expired"
            await hs.drain()
            await server.drain()

    asyncio.run(go())
    assert server.stats.expired == 1
    # it never reached the engine: no first token, no slot, no KV
    doomed = [r for r in controller.all_requests() if r.expired][0]
    assert doomed.ttft() is None and doomed.finished()
    assert eng.block_mgr.used_blocks == 0
    # the expired request is an attainment miss; the hog met its SLO
    assert controller.slo_attainment(time.monotonic()) == pytest.approx(0.5)


def test_dead_on_arrival_is_rejected_at_the_door():
    inst = InstanceInfo(0, {ARCH: _hw()}, ARCH, VirtualQueue(0))
    controller = QLMController(
        [inst], QLMConfig(reschedule_on_arrival=False))
    srv = AsyncServer(controller, [_StubAgent()], FrontendConfig())

    async def go():
        r = _req(seed=5)
        r.arrival_time = time.monotonic() - 100.0      # deadline long gone
        s = await srv.submit(r)
        assert s.status == "rejected" and r.expired
        assert srv.stats.rejected_deadline == 1

    asyncio.run(go())


# ---------------------------------------------------------------------------
# multi-turn sessions ride the prefix cache
# ---------------------------------------------------------------------------

def test_session_follow_up_turns_hit_prefix_cache(tiny):
    model, params = tiny
    eng, controller, server = _stack(model, params, slots=2)
    rng = np.random.default_rng(11)
    sess = Session(session_id=0, model=ARCH, slo_class="interactive",
                   turn_prompts=[rng.integers(0, 100, size=16).tolist()
                                 for _ in range(3)],
                   max_new_tokens=8, arrival_time=time.monotonic())

    async def go():
        async with server:
            await run_session(server, sess)
            await server.drain()

    asyncio.run(go())
    assert len(sess.requests) == 3
    assert all(r.finished() and r.session_id == 0 for r in sess.requests)
    assert [r.turn for r in sess.requests] == [0, 1, 2]
    # turn N+1 carries turn N's prompt+output as its prompt prefix; the
    # freed-block cache keeps the finished turn's chain matchable
    assert eng.stats.prefix_hits >= 2
    assert eng.stats.prefix_shared_tokens >= 2 * 16
    # each turn's prompt strictly grows by the previous turn's tokens
    p0, p1, p2 = [list(r.prompt_tokens) for r in sess.requests]
    assert p1[:len(p0)] == p0 + list(sess.requests[0].output_tokens)[:0] \
        or p1[:len(p0) + 8] == p0 + list(sess.requests[0].output_tokens)
    assert p2[:len(p1) + 8] == p1 + list(sess.requests[1].output_tokens)
    assert eng.block_mgr.used_blocks == 0


# ---------------------------------------------------------------------------
# clean shutdown + streaming
# ---------------------------------------------------------------------------

def test_drain_stop_clean_shutdown_streams_all_tokens(tiny):
    model, params = tiny
    eng, controller, server = _stack(model, params, slots=4)

    async def go():
        async with server:
            streams = [await server.submit(_req(n_prompt=8, n_new=6, seed=i))
                       for i in range(3)]
            toks = [await s.drain() for s in streams]
            await server.drain()
            return toks

    toks = asyncio.run(go())
    assert all(len(t) == 6 for t in toks)
    assert server.stats.tokens_streamed == 18
    assert not server._live and server._task is None
    assert server.stats.accepted == 3 and server.stats.rejected == 0
    assert eng.block_mgr.used_blocks == 0


def test_stop_cancels_outstanding(tiny):
    model, params = tiny
    eng, controller, server = _stack(model, params, slots=2)

    async def go():
        await server.start()
        s = await server.submit(_req(n_prompt=10, n_new=64, seed=7))
        # wait for it to start decoding, then hard-stop
        while s.request.first_token_time is None:
            await asyncio.sleep(0.005)
        await server.stop(cancel_outstanding=True)
        return s

    s = asyncio.run(go())
    assert s.status == "cancelled"
    assert eng.block_mgr.used_blocks == 0
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks


# ---------------------------------------------------------------------------
# the acceptance bar: 2x overload, async > sync on interactive attainment
# ---------------------------------------------------------------------------

def _overload_args(requests):
    # reschedule_cooldown longer than the run throttles the controller's
    # on-arrival re-solve for BOTH runners, so the comparison isolates
    # what the async front end adds: shedding on its own clock
    # (shed_cooldown) plus deadline expiry of unservable requests
    return argparse.Namespace(
        seed=0, rate=400.0, requests=requests, max_new_tokens=2,
        batch_new_tokens=100, slots=2, decode_burst=8, backend="paged-xla",
        prefix_sharing=True, instances=1, queue_depth=512,
        shed_policy="defer", shed_cooldown=0.15, admit_drain="off",
        sessions=0, session_turns=0, think_time=0.0, slo_scale=0.08,
        reschedule_cooldown=1e9, max_wall=90.0)


def test_async_beats_sync_interactive_attainment_under_overload(tiny):
    from repro.launch.async_serve import run_async, run_sync
    from repro.launch.serve import calibrate_registry

    model, params = tiny
    registry = {ARCH: (model, params)}
    args = _overload_args(400)
    ecfg = EngineConfig(max_slots=args.slots, max_seq_len=128,
                        attention_backend=args.backend,
                        prefix_sharing=args.prefix_sharing)
    hw = calibrate_registry(registry, ecfg)

    # warmup pass: populate the persistent XLA cache for every shape each
    # runner compiles (the async shed/evict/resume paths hit shapes the
    # sync loop never does); the measured runs then compare scheduling
    warm = _overload_args(40)
    run_sync(warm, registry, hw, [ARCH])
    asyncio.run(run_async(warm, registry, hw, [ARCH]))

    sync_stats = run_sync(args, registry, hw, [ARCH])
    async_stats = asyncio.run(run_async(args, registry, hw, [ARCH]))

    assert async_stats["clean_shutdown"] == 1
    assert async_stats["kv_blocks_leaked"] == 0
    assert async_stats["tokens_streamed"] > 0
    # same seed, same workload: the shedding/deadline-aware front end must
    # strictly beat the synchronous driver on interactive attainment
    assert async_stats["attainment_interactive"] \
        > sync_stats["attainment_interactive"], (async_stats, sync_stats)


# ---------------------------------------------------------------------------
# serve-loop crash propagation: a dead loop must fail clients, not hang them
# ---------------------------------------------------------------------------

def test_serve_loop_crash_fails_waiters_instead_of_hanging(tiny):
    model, params = tiny
    eng, controller, server = _stack(model, params, slots=2)

    class _Boom(RuntimeError):
        pass

    async def go():
        await server.start()
        stream = await server.submit(_req(n_prompt=6, n_new=64, seed=11))

        def explode():
            raise _Boom("engine round blew up")

        # crash the next engine round; before the _run crash handler
        # existed this left stream.drain() and server.drain() awaiting
        # tokens forever (observed: an InvariantViolation inside the loop
        # hung the whole suite)
        server.agents[0].run_iteration = explode
        with pytest.raises(_Boom):
            await asyncio.wait_for(stream.drain(), timeout=10)
        with pytest.raises(_Boom):
            await asyncio.wait_for(server.drain(), timeout=10)
        # new submissions fail fast instead of queueing onto a dead loop
        with pytest.raises(_Boom):
            await server.submit(_req(seed=12))
        # the task's own exception was consumed above; swallow it so
        # asyncio.run doesn't log "exception was never retrieved"
        with pytest.raises(_Boom):
            await server._task

    asyncio.run(go())
