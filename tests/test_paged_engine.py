"""Paged (block-table) attention backends in the real engine.

The acceptance bar for the paged KV pool: for mixed prompt lengths with
mid-stream eviction / resume, ``paged-pallas`` (interpret mode on CPU) and
the dense ``xla`` backend produce IDENTICAL tokens, engine KV capacity
follows ``kv_blocks * block_size`` independent of
``max_slots * max_seq_len``, and freed pages are physically reused.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


BACKENDS = ("xla", "pallas", "paged-xla", "paged-pallas")


def _mk_engine(model, params, **kw):
    cfg = EngineConfig(**{"max_slots": 4, "max_seq_len": 64,
                          "prefill_chunk_tokens": 16, "block_size": 8, **kw})
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def _req(prompt, n=8):
    return Request(prompt_tokens=list(prompt), model="m1", slo=1e9,
                   max_new_tokens=n)


def _run_to_completion(eng, reqs, max_steps=200):
    for _ in range(max_steps):
        eng.step()
        if all(r.finished() for r in reqs):
            return
    raise AssertionError("requests did not finish")


# ---------------------------------------------------------------------------
# token parity (the acceptance criterion)
# ---------------------------------------------------------------------------

def _serve_with_evict_resume(model, params, backend, prompts, n=6):
    """Admit mixed-length prompts, evict one request mid-stream, resume it,
    and drain; returns each request's output tokens."""
    eng = _mk_engine(model, params, attention_backend=backend)
    reqs = [_req(p, n=n) for p in prompts]
    for r in reqs:
        assert eng.admit(r)
    eng.step()
    eng.step()                                     # r1 is mid-stream now
    ev = eng.evict_request(reqs[1].req_id)
    assert ev is reqs[1] and reqs[1].snapshot is not None
    eng.step()                                     # others advance meanwhile
    assert eng.admit(reqs[1])                      # snapshot resume
    assert eng.stats.resumes == 1
    _run_to_completion(eng, reqs)
    assert eng.block_mgr.used_blocks == 0
    return [r.output_tokens for r in reqs]


def test_paged_backends_match_dense_tokens_with_eviction(small_model):
    _, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 17, 30, 9)]
    want = _serve_with_evict_resume(model, params, "xla", prompts)
    assert all(len(t) == 6 for t in want)
    for backend in ("paged-xla", "paged-pallas"):
        got = _serve_with_evict_resume(model, params, backend, prompts)
        assert got == want, backend


def test_paged_quant_matches_dense_quant_tokens(small_model):
    """int8 page pool (scale pages + fused-dequant paged kernel) matches the
    dense int8 cache token-for-token."""
    cfg = dataclasses.replace(
        ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64),
        kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (5, 21)]
    outs = {}
    for backend in ("xla", "paged-xla", "paged-pallas"):
        eng = _mk_engine(model, params, attention_backend=backend, max_slots=2)
        reqs = [_req(p, n=5) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        _run_to_completion(eng, reqs)
        outs[backend] = [r.output_tokens for r in reqs]
    assert outs["paged-xla"] == outs["xla"]
    assert outs["paged-pallas"] == outs["xla"]


# ---------------------------------------------------------------------------
# capacity decoupling + physical page reuse
# ---------------------------------------------------------------------------

def test_paged_capacity_tracks_blocks_not_slots(small_model):
    """Dense cache bytes scale with max_slots * max_seq_len; the page pool's
    scale with kv_blocks * block_size only."""
    _, model, params = small_model

    def cache_bytes(eng):
        return sum(leaf.nbytes for leaf in jax.tree.leaves(eng.cache))

    dense_small = _mk_engine(model, params, max_slots=2, max_seq_len=64)
    dense_big = _mk_engine(model, params, max_slots=8, max_seq_len=256)
    assert cache_bytes(dense_big) == 16 * cache_bytes(dense_small)

    paged_small = _mk_engine(model, params, max_slots=2, max_seq_len=64,
                             kv_blocks=16, attention_backend="paged-xla")
    paged_big = _mk_engine(model, params, max_slots=8, max_seq_len=256,
                           kv_blocks=16, attention_backend="paged-xla")
    assert cache_bytes(paged_big) == cache_bytes(paged_small)
    assert paged_big.block_mgr.token_capacity == 16 * 8  # kv_blocks * block_size

    # an 8-slot/256-seq engine with a 4x-oversubscribed pool still serves
    rng = np.random.default_rng(2)
    reqs = [_req(rng.integers(0, 100, size=6).tolist(), n=3) for _ in range(3)]
    for r in reqs:
        assert paged_big.admit(r)
    _run_to_completion(paged_big, reqs)
    assert paged_big.block_mgr.used_blocks == 0


def test_freed_pages_are_physically_reused(small_model):
    """Evict A -> admit B (B overwrites A's freed pages) -> finish B ->
    resume A: A must still produce the uninterrupted run's tokens, because
    its eviction snapshot copied the page CONTENTS, not just the table."""
    _, model, params = small_model
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(0, 100, size=20).tolist()
    prompt_b = rng.integers(0, 100, size=20).tolist()

    base = _mk_engine(model, params, attention_backend="paged-pallas",
                      kv_blocks=8, max_slots=1)
    r_base = _req(prompt_a, n=8)
    assert base.admit(r_base)
    _run_to_completion(base, [r_base])

    eng = _mk_engine(model, params, attention_backend="paged-pallas",
                     kv_blocks=8, max_slots=1)  # pool barely fits ONE request
    r_a = _req(prompt_a, n=8)
    assert eng.admit(r_a)
    for _ in range(4):
        eng.step()
    pages_a = set(eng.block_mgr.block_table(r_a.req_id))
    eng.evict_request(r_a.req_id)

    r_b = _req(prompt_b, n=8)
    assert eng.admit(r_b)
    eng.step()
    eng.step()
    # with an 8-block pool (A held >= 3 of them), B's allocation MUST have
    # recycled pages A physically occupied a moment ago
    pages_b = set(eng.block_mgr.block_table(r_b.req_id))
    assert pages_a & pages_b, (pages_a, pages_b)
    _run_to_completion(eng, [r_b])
    assert eng.stats.evictions == 1

    assert eng.admit(r_a)                        # resume over recycled pages
    pages_a2 = set(eng.block_mgr.block_table(r_a.req_id))
    assert pages_a2 & pages_b                    # ...recycled again
    _run_to_completion(eng, [r_a])
    assert r_a.output_tokens == r_base.output_tokens


def test_paged_eviction_snapshot_is_page_granular(small_model):
    """The snapshot copies exactly the sequence's pages (n_pages on axis 1),
    not a max_seq_len stripe."""
    _, model, params = small_model
    eng = _mk_engine(model, params, attention_backend="paged-xla")
    r = _req(list(range(20)), n=8)
    assert eng.admit(r)
    eng.step()
    eng.step()
    n_pages = len(eng.block_mgr.block_table(r.req_id))
    eng.evict_request(r.req_id)
    leaf = jax.tree.leaves(r.snapshot["cache"])[0]
    assert leaf.shape[1] == n_pages
    assert r.snapshot["layout"] == "paged"
    assert r.snapshot["kv_tokens"] > 0


# ---------------------------------------------------------------------------
# configuration gating
# ---------------------------------------------------------------------------

def test_paged_backend_rejects_unsupported_configs(small_model):
    _, model, params = small_model
    swa_model = build_model(
        ARCHITECTURES["h2o-danube-1.8b"].reduced(num_layers=1, d_model=64))
    ssm_model = build_model(
        ARCHITECTURES["mamba2-130m"].reduced(num_layers=1, d_model=64))
    with pytest.raises(ValueError):   # rolling SWA cache can't page (yet)
        _mk_engine(swa_model, swa_model.init(jax.random.key(0)),
                   attention_backend="paged-xla")
    with pytest.raises(ValueError):   # SSM state has no pageable KV
        _mk_engine(ssm_model, ssm_model.init(jax.random.key(0)),
                   attention_backend="paged-xla")
    with pytest.raises(ValueError):   # paged requires chunked prefill
        _mk_engine(model, params, attention_backend="paged-pallas",
                   prefill_chunk_tokens=0)
    with pytest.raises(ValueError):   # still validates unknown names
        _mk_engine(model, params, attention_backend="paged-cuda")


def test_paged_refuses_extras_requests_gracefully(small_model):
    """A request carrying modality extras needs the legacy single-shot
    prefill (no paged variant): can_admit refuses it so a pull loop hands
    it back via pushback instead of step() exploding mid-serve."""
    _, model, params = small_model
    eng = _mk_engine(model, params, attention_backend="paged-xla")
    r = _req([1, 2, 3], n=4)
    r.extras = {"patch_embeds": np.zeros((2, 4), np.float32)}
    assert not eng.can_admit(r)
    assert not eng.admit(r)
    queue = [r]
    eng.pull_source = lambda: queue.pop(0) if queue else None
    eng.step()                                   # must not raise
    assert eng.take_pushback() is r
    with pytest.raises(ValueError):              # explicit call still loud
        eng.admit(_req([1, 2], n=2), extras={"patch_embeds": np.zeros((2, 4))})


def test_cross_layout_snapshot_falls_back_or_raises(small_model):
    """A mid-prefill dense snapshot re-admitted to a paged engine recomputes
    (page contents can't be transplanted); a mid-decode one raises."""
    _, model, params = small_model
    dense_eng = _mk_engine(model, params)
    r = _req(list(range(24)), n=6)
    assert dense_eng.admit(r)
    dense_eng.step()                              # one chunk done
    dense_eng.evict_request(r.req_id)
    assert r.snapshot["layout"] == "dense" and r.generated == 0

    paged_eng = _mk_engine(model, params, attention_backend="paged-xla")
    assert paged_eng.admit(r)                     # falls back to fresh prefill
    assert paged_eng.stats.resumes == 0
    _run_to_completion(paged_eng, [r])
    assert len(r.output_tokens) == 6

    r2 = _req(list(range(5)), n=6)
    assert dense_eng.admit(r2)
    dense_eng.step()
    dense_eng.step()
    assert r2.generated > 0
    dense_eng.evict_request(r2.req_id)
    with pytest.raises(ValueError):
        _mk_engine(model, params, attention_backend="paged-xla").admit(r2)


# ---------------------------------------------------------------------------
# prefix sharing: refcounted shared-prefix pages + copy-on-write (ISSUE 5)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    """1-layer/64-dim model: the sharing matrix below builds many engines."""
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    return cfg, model, params


def _shared_prompts(n=4, shared_blocks=2, bs=8):
    """n prompts sharing a ``shared_blocks``-block leading run, with
    distinct-length private tails."""
    rng = np.random.default_rng(5)
    common = rng.integers(0, 100, size=shared_blocks * bs).tolist()
    tails = (5, 9, 3, 12, 7, 11, 2, 8)
    return [common + rng.integers(0, 100, size=t).tolist()
            for t in tails[:n]]


def _serve_shared(model, params, backend, *, sharing, burst=1, evict=True,
                  n_new=12):
    """Leader admitted first (its chunks publish the shared blocks), then
    three followers; one sharer is evicted and resumed mid-stream."""
    eng = _mk_engine(model, params, attention_backend=backend,
                     prefix_sharing=sharing, decode_burst=burst)
    reqs = [_req(p, n=n_new) for p in _shared_prompts()]
    assert eng.admit(reqs[0])
    while eng.prefilling_slots():
        eng.steps()
    for r in reqs[1:]:
        assert eng.admit(r)
    eng.steps()
    eng.steps()
    if evict:
        ev = eng.evict_request(reqs[1].req_id)        # a sharer, mid-stream
        assert ev is reqs[1] and reqs[1].snapshot is not None
        eng.steps()                                   # others advance
        assert eng.admit(reqs[1])                     # snapshot resume
        assert eng.stats.resumes == 1
    for _ in range(300):
        eng.steps()
        if all(r.finished() for r in reqs):
            break
    assert all(r.finished() for r in reqs)
    assert eng.block_mgr.used_blocks == 0
    return [r.output_tokens for r in reqs], eng


def test_prefix_sharing_token_parity_all_backends(tiny_model):
    """The satellite acceptance bar: byte-identical tokens with
    prefix_sharing on vs off across all four backends, including COW
    divergence after the shared region, mid-stream evict+resume of one
    sharer, and decode_burst in {1, 4}."""
    _, model, params = tiny_model
    want, _ = _serve_shared(model, params, "xla", sharing=False)
    assert all(len(t) == 12 for t in want)
    for backend in BACKENDS:
        runs = [(False, 1), (True, 1), (True, 4)]
        if backend == "xla":
            runs.remove((False, 1))                   # that's `want` itself
        for sharing, burst in runs:
            got, eng = _serve_shared(model, params, backend,
                                     sharing=sharing, burst=burst)
            assert got == want, (backend, sharing, burst)
            if eng.prefix_sharing:
                # all three followers matched the leader's 2-block chain
                assert eng.stats.prefix_hits == 3, (backend, burst)
                assert eng.stats.prefix_shared_tokens == 3 * 16
            else:
                assert eng.stats.prefix_hits == 0


def test_prefix_sharing_int8_parity(tiny_model):
    """int8 page pools share scale pages along with the quantized KV
    pages: token parity with sharing on vs off."""
    cfg = dataclasses.replace(
        ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64),
        kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    want, _ = _serve_shared(model, params, "paged-xla", sharing=False)
    for backend in ("paged-xla", "paged-pallas"):
        got, eng = _serve_shared(model, params, backend, sharing=True)
        assert got == want, backend
        assert eng.stats.prefix_hits == 3


def test_prefix_sharing_block_usage_acceptance(tiny_model):
    """The ISSUE acceptance criterion: 8 requests sharing a 75%-length
    prefix occupy ~ 1 shared chain + 8 private tails during the prompt
    phase, vs 8 full chains with sharing off."""
    _, model, params = tiny_model
    rng = np.random.default_rng(7)
    common = rng.integers(0, 100, size=24).tolist()            # 3 blocks
    prompts = [common + rng.integers(0, 100, size=8).tolist()  # 32 tokens
               for _ in range(8)]

    def prompt_blocks(sharing):
        eng = _mk_engine(model, params, attention_backend="paged-xla",
                         prefix_sharing=sharing, max_slots=8)
        # max_new sized so no request retires before the prompt-phase pool
        # measurement (the leader decodes while followers prefill)
        reqs = [_req(p, n=8) for p in prompts]
        assert eng.admit(reqs[0])
        while eng.prefilling_slots():
            eng.step()
        for r in reqs[1:]:
            assert eng.admit(r)
        while eng.prefilling_slots():
            eng.step()
        used = eng.block_mgr.used_blocks
        for _ in range(60):
            eng.step()
            if all(r.finished() for r in reqs):
                break
        assert all(r.finished() for r in reqs)
        return used, [r.output_tokens for r in reqs], eng.stats

    used_on, toks_on, stats = prompt_blocks(True)
    used_off, toks_off, _ = prompt_blocks(False)
    # blocks_needed(33) = 5 per chain: 8 full chains = 40; shared = the
    # 3-block chain + 8 private (2-block) tails = 19
    assert used_off == 40
    assert used_on == 3 + 8 * 2
    assert toks_on == toks_off
    assert stats.prefix_hits == 7
    assert stats.prefix_shared_tokens == 7 * 24


def test_shared_eviction_pins_survive_sharer_completion(tiny_model):
    """Evicting a sharer pins the shared chain instead of freeing or
    copying it: the snapshot holds ONLY the private tail pages, and the
    chain stays alive for the resume even after every other sharer
    finishes and frees its references."""
    _, model, params = tiny_model
    prompts = _shared_prompts(n=2)
    base = _mk_engine(model, params, attention_backend="paged-pallas",
                      prefix_sharing=False)
    base_reqs = [_req(p, n=8) for p in prompts]
    for r in base_reqs:
        assert base.admit(r)
    _run_to_completion(base, base_reqs)
    want = [r.output_tokens for r in base_reqs]

    eng = _mk_engine(model, params, attention_backend="paged-pallas",
                     prefix_sharing=True)
    ra, rb = [_req(p, n=8) for p in prompts]
    assert eng.admit(ra)
    while eng.prefilling_slots():
        eng.step()
    assert eng.admit(rb)
    eng.step()
    eng.step()
    assert eng.evict_request(rb.req_id) is rb
    snap = rb.snapshot
    assert snap["pinned"] and len(snap["pinned"]) == 2    # the shared chain
    # only privately-owned pages were copied to host memory
    n_private = len(eng.block_mgr.block_table(ra.req_id)) - 2
    assert jax.tree.leaves(snap["cache"])[0].shape[1] \
        == eng.block_mgr.blocks_needed(snap["kv_tokens"]) - 2
    assert n_private >= 1
    # drain the other sharer COMPLETELY while rb is evicted
    for _ in range(60):
        eng.step()
        if ra.finished():
            break
    assert ra.finished()
    # the pinned chain is still resident (refcount 1 = the pin itself)
    assert all(eng.block_mgr.ref_count(b) == 1 for b in snap["pinned"])
    assert eng.admit(rb)                                  # pins transfer back
    for _ in range(60):
        eng.step()
        if rb.finished():
            break
    assert rb.finished()
    assert [ra.output_tokens, rb.output_tokens] == want
    assert eng.block_mgr.used_blocks == 0


def test_pinned_snapshot_is_engine_local(tiny_model):
    """A prefix-shared snapshot pins pages in its source pool: another
    engine must refuse it mid-decode (ValueError) and recompute it
    mid-prefill (releasing the foreign pins)."""
    _, model, params = tiny_model
    prompts = _shared_prompts(n=2)
    eng_a = _mk_engine(model, params, attention_backend="paged-xla",
                       prefix_sharing=True)
    ra, rb = [_req(p, n=6) for p in prompts]
    assert eng_a.admit(ra)
    while eng_a.prefilling_slots():
        eng_a.step()
    assert eng_a.admit(rb)
    eng_a.step()
    eng_a.step()
    assert rb.generated > 0
    eng_a.evict_request(rb.req_id)
    assert rb.snapshot["pinned"]

    eng_b = _mk_engine(model, params, attention_backend="paged-xla",
                       prefix_sharing=True)
    assert not eng_b.can_admit(rb)       # pull loop gets a graceful refusal
    assert not eng_b.admit(rb)           # admit's can_admit gate holds too
    assert rb.snapshot is not None       # ... without consuming the snapshot
    assert eng_a.admit(rb)               # the owning engine still resumes it
    for _ in range(60):
        eng_a.step()
        if ra.finished() and rb.finished():
            break
    assert ra.finished() and rb.finished()

    # mid-prefill foreign resume: recompute, releasing the foreign pins
    long_prompt = prompts[0] + list(range(30))
    rc = _req(prompts[0], n=4)
    rd = _req(long_prompt, n=4)
    assert eng_a.admit(rc)
    while eng_a.prefilling_slots():
        eng_a.step()
    assert eng_a.admit(rd)               # shares rc's chain
    eng_a.evict_request(rd.req_id)       # mid-prefill (long tail, chunk 16)
    assert rd.snapshot["pinned"] and rd.generated == 0
    pinned = list(rd.snapshot["pinned"])
    refs_before = [eng_a.block_mgr.ref_count(b) for b in pinned]
    assert eng_b.admit(rd)               # drops the snapshot, recomputes
    assert rd.snapshot is None
    # the discard released eng_a's pins (refcounts dropped by one)
    refs_after = [eng_a.block_mgr.ref_count(b) for b in pinned]
    assert refs_after == [r - 1 for r in refs_before]
    for _ in range(120):
        eng_a.step()
        eng_b.step()
        if rc.finished() and rd.finished():
            break
    assert rc.finished() and rd.finished()


def test_fork_slot_cow_divergence(tiny_model):
    """fork_slot clones a running decode with zero page copies; the COW of
    the partial tail block isolates the two writers, and greedy decoding
    makes the clone continue exactly like the source (both match the
    unforked baseline)."""
    _, model, params = tiny_model
    prompt = _shared_prompts(n=1)[0]
    base = _mk_engine(model, params, attention_backend="paged-pallas",
                      prefix_sharing=False)
    r_base = _req(prompt, n=10)
    assert base.admit(r_base)
    for _ in range(60):
        base.step()
        if r_base.finished():
            break
    assert r_base.finished()

    eng = _mk_engine(model, params, attention_backend="paged-pallas",
                     prefix_sharing=True)
    src = _req(prompt, n=10)
    assert eng.admit(src)
    while eng.prefilling_slots():
        eng.step()
    eng.step()
    eng.step()
    clone = eng.fork_slot(0)
    assert clone is not None and clone.output_tokens == src.output_tokens
    assert eng.stats.forks == 1
    for _ in range(60):
        eng.step()
        if src.finished() and clone.finished():
            break
    assert src.finished() and clone.finished()
    assert src.output_tokens == r_base.output_tokens
    assert clone.output_tokens == r_base.output_tokens
    assert eng.stats.cow_copies >= 1     # the tail COW actually fired
    assert eng.block_mgr.used_blocks == 0

    # gating: dense engines (sharing inert) refuse fork_slot
    dense = _mk_engine(model, params, attention_backend="xla")
    rd = _req([1, 2, 3], n=2)
    assert dense.admit(rd)
    with pytest.raises(ValueError):
        dense.fork_slot(0)


def test_pinned_snapshot_survives_model_swap(tiny_model):
    """A sharer evicted mid-decode must stay resumable across a model-swap
    cycle: the pool reset would kill the snapshot's pins, so swap_model
    first materializes the pinned pages INTO the snapshot (restoring the
    pre-sharing self-contained-snapshot behavior), and the resumed run is
    token-identical once the engine swaps back to the original weights."""
    _, model, params = tiny_model
    params_b = model.init(jax.random.key(9))
    prompts = _shared_prompts(n=2)

    base = _mk_engine(model, params, attention_backend="paged-xla",
                      prefix_sharing=False)
    base_reqs = [_req(p, n=8) for p in prompts]
    for r in base_reqs:
        assert base.admit(r)
    _run_to_completion(base, base_reqs)
    want = [r.output_tokens for r in base_reqs]

    eng = _mk_engine(model, params, attention_backend="paged-xla",
                     prefix_sharing=True)
    ra, rb = [_req(p, n=8) for p in prompts]
    assert eng.admit(ra)
    while eng.prefilling_slots():
        eng.step()
    assert eng.admit(rb)
    eng.step()
    eng.step()
    assert eng.evict_request(rb.req_id) is rb
    n_chain = eng.block_mgr.blocks_needed(rb.snapshot["kv_tokens"])
    assert rb.snapshot["pinned"]
    for _ in range(60):                       # finish the other sharer
        eng.step()
        if ra.finished():
            break
    assert ra.finished()

    eng.swap_model(model, params_b, "m2")     # pool reset kills the epoch...
    assert rb.snapshot["pinned"] == []        # ...but the pins were
    leaf = jax.tree.leaves(rb.snapshot["cache"])[0]
    assert leaf.shape[1] == n_chain           # materialized into the snap
    eng.swap_model(model, params, "m1")       # back to the original weights

    assert eng.can_admit(rb)
    assert eng.admit(rb)                      # plain self-contained restore
    for _ in range(60):
        eng.step()
        if rb.finished():
            break
    assert rb.finished()
    assert rb.output_tokens == want[1]
