"""Paged (block-table) attention backends in the real engine.

The acceptance bar for the paged KV pool: for mixed prompt lengths with
mid-stream eviction / resume, ``paged-pallas`` (interpret mode on CPU) and
the dense ``xla`` backend produce IDENTICAL tokens, engine KV capacity
follows ``kv_blocks * block_size`` independent of
``max_slots * max_seq_len``, and freed pages are physically reused.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_engine(model, params, **kw):
    cfg = EngineConfig(**{"max_slots": 4, "max_seq_len": 64,
                          "prefill_chunk_tokens": 16, "block_size": 8, **kw})
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def _req(prompt, n=8):
    return Request(prompt_tokens=list(prompt), model="m1", slo=1e9,
                   max_new_tokens=n)


def _run_to_completion(eng, reqs, max_steps=200):
    for _ in range(max_steps):
        eng.step()
        if all(r.finished() for r in reqs):
            return
    raise AssertionError("requests did not finish")


# ---------------------------------------------------------------------------
# token parity (the acceptance criterion)
# ---------------------------------------------------------------------------

def _serve_with_evict_resume(model, params, backend, prompts, n=6):
    """Admit mixed-length prompts, evict one request mid-stream, resume it,
    and drain; returns each request's output tokens."""
    eng = _mk_engine(model, params, attention_backend=backend)
    reqs = [_req(p, n=n) for p in prompts]
    for r in reqs:
        assert eng.admit(r)
    eng.step()
    eng.step()                                     # r1 is mid-stream now
    ev = eng.evict_request(reqs[1].req_id)
    assert ev is reqs[1] and reqs[1].snapshot is not None
    eng.step()                                     # others advance meanwhile
    assert eng.admit(reqs[1])                      # snapshot resume
    assert eng.stats.resumes == 1
    _run_to_completion(eng, reqs)
    assert eng.block_mgr.used_blocks == 0
    return [r.output_tokens for r in reqs]


def test_paged_backends_match_dense_tokens_with_eviction(small_model):
    _, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 17, 30, 9)]
    want = _serve_with_evict_resume(model, params, "xla", prompts)
    assert all(len(t) == 6 for t in want)
    for backend in ("paged-xla", "paged-pallas"):
        got = _serve_with_evict_resume(model, params, backend, prompts)
        assert got == want, backend


def test_paged_quant_matches_dense_quant_tokens(small_model):
    """int8 page pool (scale pages + fused-dequant paged kernel) matches the
    dense int8 cache token-for-token."""
    cfg = dataclasses.replace(
        ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64),
        kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (5, 21)]
    outs = {}
    for backend in ("xla", "paged-xla", "paged-pallas"):
        eng = _mk_engine(model, params, attention_backend=backend, max_slots=2)
        reqs = [_req(p, n=5) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        _run_to_completion(eng, reqs)
        outs[backend] = [r.output_tokens for r in reqs]
    assert outs["paged-xla"] == outs["xla"]
    assert outs["paged-pallas"] == outs["xla"]


# ---------------------------------------------------------------------------
# capacity decoupling + physical page reuse
# ---------------------------------------------------------------------------

def test_paged_capacity_tracks_blocks_not_slots(small_model):
    """Dense cache bytes scale with max_slots * max_seq_len; the page pool's
    scale with kv_blocks * block_size only."""
    _, model, params = small_model

    def cache_bytes(eng):
        return sum(leaf.nbytes for leaf in jax.tree.leaves(eng.cache))

    dense_small = _mk_engine(model, params, max_slots=2, max_seq_len=64)
    dense_big = _mk_engine(model, params, max_slots=8, max_seq_len=256)
    assert cache_bytes(dense_big) == 16 * cache_bytes(dense_small)

    paged_small = _mk_engine(model, params, max_slots=2, max_seq_len=64,
                             kv_blocks=16, attention_backend="paged-xla")
    paged_big = _mk_engine(model, params, max_slots=8, max_seq_len=256,
                           kv_blocks=16, attention_backend="paged-xla")
    assert cache_bytes(paged_big) == cache_bytes(paged_small)
    assert paged_big.block_mgr.token_capacity == 16 * 8  # kv_blocks * block_size

    # an 8-slot/256-seq engine with a 4x-oversubscribed pool still serves
    rng = np.random.default_rng(2)
    reqs = [_req(rng.integers(0, 100, size=6).tolist(), n=3) for _ in range(3)]
    for r in reqs:
        assert paged_big.admit(r)
    _run_to_completion(paged_big, reqs)
    assert paged_big.block_mgr.used_blocks == 0


def test_freed_pages_are_physically_reused(small_model):
    """Evict A -> admit B (B overwrites A's freed pages) -> finish B ->
    resume A: A must still produce the uninterrupted run's tokens, because
    its eviction snapshot copied the page CONTENTS, not just the table."""
    _, model, params = small_model
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(0, 100, size=20).tolist()
    prompt_b = rng.integers(0, 100, size=20).tolist()

    base = _mk_engine(model, params, attention_backend="paged-pallas",
                      kv_blocks=8, max_slots=1)
    r_base = _req(prompt_a, n=8)
    assert base.admit(r_base)
    _run_to_completion(base, [r_base])

    eng = _mk_engine(model, params, attention_backend="paged-pallas",
                     kv_blocks=8, max_slots=1)  # pool barely fits ONE request
    r_a = _req(prompt_a, n=8)
    assert eng.admit(r_a)
    for _ in range(4):
        eng.step()
    pages_a = set(eng.block_mgr.block_table(r_a.req_id))
    eng.evict_request(r_a.req_id)

    r_b = _req(prompt_b, n=8)
    assert eng.admit(r_b)
    eng.step()
    eng.step()
    # with an 8-block pool (A held >= 3 of them), B's allocation MUST have
    # recycled pages A physically occupied a moment ago
    pages_b = set(eng.block_mgr.block_table(r_b.req_id))
    assert pages_a & pages_b, (pages_a, pages_b)
    _run_to_completion(eng, [r_b])
    assert eng.stats.evictions == 1

    assert eng.admit(r_a)                        # resume over recycled pages
    pages_a2 = set(eng.block_mgr.block_table(r_a.req_id))
    assert pages_a2 & pages_b                    # ...recycled again
    _run_to_completion(eng, [r_a])
    assert r_a.output_tokens == r_base.output_tokens


def test_paged_eviction_snapshot_is_page_granular(small_model):
    """The snapshot copies exactly the sequence's pages (n_pages on axis 1),
    not a max_seq_len stripe."""
    _, model, params = small_model
    eng = _mk_engine(model, params, attention_backend="paged-xla")
    r = _req(list(range(20)), n=8)
    assert eng.admit(r)
    eng.step()
    eng.step()
    n_pages = len(eng.block_mgr.block_table(r.req_id))
    eng.evict_request(r.req_id)
    leaf = jax.tree.leaves(r.snapshot["cache"])[0]
    assert leaf.shape[1] == n_pages
    assert r.snapshot["layout"] == "paged"
    assert r.snapshot["kv_tokens"] > 0


# ---------------------------------------------------------------------------
# configuration gating
# ---------------------------------------------------------------------------

def test_paged_backend_rejects_unsupported_configs(small_model):
    _, model, params = small_model
    swa_model = build_model(
        ARCHITECTURES["h2o-danube-1.8b"].reduced(num_layers=1, d_model=64))
    ssm_model = build_model(
        ARCHITECTURES["mamba2-130m"].reduced(num_layers=1, d_model=64))
    with pytest.raises(ValueError):   # rolling SWA cache can't page (yet)
        _mk_engine(swa_model, swa_model.init(jax.random.key(0)),
                   attention_backend="paged-xla")
    with pytest.raises(ValueError):   # SSM state has no pageable KV
        _mk_engine(ssm_model, ssm_model.init(jax.random.key(0)),
                   attention_backend="paged-xla")
    with pytest.raises(ValueError):   # paged requires chunked prefill
        _mk_engine(model, params, attention_backend="paged-pallas",
                   prefill_chunk_tokens=0)
    with pytest.raises(ValueError):   # still validates unknown names
        _mk_engine(model, params, attention_backend="paged-cuda")


def test_paged_refuses_extras_requests_gracefully(small_model):
    """A request carrying modality extras needs the legacy single-shot
    prefill (no paged variant): can_admit refuses it so a pull loop hands
    it back via pushback instead of step() exploding mid-serve."""
    _, model, params = small_model
    eng = _mk_engine(model, params, attention_backend="paged-xla")
    r = _req([1, 2, 3], n=4)
    r.extras = {"patch_embeds": np.zeros((2, 4), np.float32)}
    assert not eng.can_admit(r)
    assert not eng.admit(r)
    queue = [r]
    eng.pull_source = lambda: queue.pop(0) if queue else None
    eng.step()                                   # must not raise
    assert eng.take_pushback() is r
    with pytest.raises(ValueError):              # explicit call still loud
        eng.admit(_req([1, 2], n=2), extras={"patch_embeds": np.zeros((2, 4))})


def test_cross_layout_snapshot_falls_back_or_raises(small_model):
    """A mid-prefill dense snapshot re-admitted to a paged engine recomputes
    (page contents can't be transplanted); a mid-decode one raises."""
    _, model, params = small_model
    dense_eng = _mk_engine(model, params)
    r = _req(list(range(24)), n=6)
    assert dense_eng.admit(r)
    dense_eng.step()                              # one chunk done
    dense_eng.evict_request(r.req_id)
    assert r.snapshot["layout"] == "dense" and r.generated == 0

    paged_eng = _mk_engine(model, params, attention_backend="paged-xla")
    assert paged_eng.admit(r)                     # falls back to fresh prefill
    assert paged_eng.stats.resumes == 0
    _run_to_completion(paged_eng, [r])
    assert len(r.output_tokens) == 6

    r2 = _req(list(range(5)), n=6)
    assert dense_eng.admit(r2)
    dense_eng.step()
    dense_eng.step()
    assert r2.generated > 0
    dense_eng.evict_request(r2.req_id)
    with pytest.raises(ValueError):
        _mk_engine(model, params, attention_backend="paged-xla").admit(r2)
