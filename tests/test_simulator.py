"""Cluster-simulator integration: the paper's qualitative claims must hold
(QLM > baselines on SLO attainment / throughput; swap amortization;
eviction un-blocks interactive HOL)."""
import pytest

from repro.data.workload import workload_a, workload_b
from repro.sim import ClusterSimulator, profiles_for

WB_MODELS = ["mistral-7b-ft", "llama-70b-ft1", "vicuna-13b-ft",
             "llama-70b-ft2", "vicuna-13b-ft2"]


def _run(policy, reqs, models, n_inst=4, **kw):
    profs = [profiles_for("a100", models) for _ in range(n_inst)]
    sim = ClusterSimulator(profs, policy, **kw)
    return sim.run(reqs)


@pytest.fixture(scope="module")
def multi_model_results():
    out = {}
    for policy in ("vllm", "edf", "shepherd", "qlm"):
        reqs = workload_b(arrival_rate=20, n_requests=400, seed=2)
        out[policy] = _run(policy, reqs, WB_MODELS)
    return out


def test_qlm_beats_baselines_on_multi_model_slo(multi_model_results):
    r = multi_model_results
    for base in ("vllm", "edf"):
        assert r["qlm"]["slo_attainment"] >= r[base]["slo_attainment"], base
    # SHEPHERD's static model partition avoids all swaps and is the closest
    # baseline on SLO for batch-only W_B (paper Fig. 13 shows the same
    # ordering); QLM must match it within noise AND beat its throughput.
    assert r["qlm"]["slo_attainment"] >= r["shepherd"]["slo_attainment"] - 0.05
    assert r["qlm"]["throughput_rps"] > r["shepherd"]["throughput_rps"]


def test_qlm_multi_model_throughput_gain(multi_model_results):
    """Paper Fig. 12: ~3-4x throughput vs vLLM in multi-model serving."""
    r = multi_model_results
    assert r["qlm"]["throughput_rps"] > 2.0 * r["vllm"]["throughput_rps"]


def test_swap_amortization(multi_model_results):
    """Insight #3 / Fig. 5: request groups cut model swaps by orders of
    magnitude vs per-request FCFS/EDF interleaving."""
    r = multi_model_results
    assert r["qlm"]["swaps"] * 10 < r["vllm"]["swaps"]


def test_single_model_all_complete():
    reqs = workload_a(arrival_rate=30, n_requests=300, seed=3)
    m = _run("qlm", reqs, ["vicuna-13b"])
    assert m["completed"] == 300
    assert m["slo_attainment"] > 0.9


def test_single_model_qlm_not_worse_when_underloaded():
    """Fig. 17 left edge: near-zero queues, QLM ≈ baselines."""
    reqs_q = workload_a(arrival_rate=2, n_requests=100, seed=4)
    reqs_v = workload_a(arrival_rate=2, n_requests=100, seed=4)
    mq = _run("qlm", reqs_q, ["vicuna-13b"])
    mv = _run("vllm", reqs_v, ["vicuna-13b"])
    assert abs(mq["slo_attainment"] - mv["slo_attainment"]) < 0.1


def test_eviction_unblocks_interactive_under_pressure():
    """Insight #2: with eviction disabled, overloaded single-instance mixed
    workloads violate more interactive SLOs."""
    from repro.core.qlm import QLMConfig
    res = {}
    for evict in (True, False):
        reqs = workload_a(arrival_rate=60, n_requests=250, seed=5)
        profs = [profiles_for("a100", ["vicuna-13b"])]
        sim = ClusterSimulator(profs, "qlm")
        if not evict:
            for inst in sim.instances:
                inst.traits = inst.traits.__class__(
                    **{**inst.traits.__dict__, "uses_eviction": False})
        res[evict] = sim.run(reqs)
    assert res[True]["slo_attainment"] >= res[False]["slo_attainment"]


def test_metrics_sanity():
    reqs = workload_a(arrival_rate=10, n_requests=120, seed=6)
    m = _run("qlm", reqs, ["vicuna-13b"], n_inst=2)
    assert 0 <= m["slo_attainment"] <= 1
    assert m["device_utilization"] >= 0
    assert m["makespan"] > 0
    assert m["token_throughput"] > 0
