"""Real-engine behaviour: continuous batching, eviction determinism, model
swapping, OOM preemption."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_engine(model, params, **kw):
    cfg = EngineConfig(**{"max_slots": 4, "max_seq_len": 64, **kw})
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def _req(prompt, n=8, model="m1"):
    return Request(prompt_tokens=list(prompt), model=model, slo=1e9,
                   max_new_tokens=n)


def test_continuous_batching_completes_all(small_model):
    _, model, params = small_model
    eng = _mk_engine(model, params)
    rng = np.random.default_rng(0)
    reqs = [_req(rng.integers(0, 100, size=rng.integers(3, 10)), n=5)
            for _ in range(7)]
    queue = list(reqs)
    eng.pull_source = lambda: queue.pop(0) if queue else None
    for _ in range(100):
        eng.step()
        if all(r.finished() for r in reqs):
            break
    assert all(r.finished() for r in reqs)
    assert all(len(r.output_tokens) == 5 for r in reqs)
    assert eng.block_mgr.used_blocks == 0  # everything freed


def test_eviction_resume_is_deterministic(small_model):
    """The paper's eviction LSO: KV snapshot => resumed request produces
    EXACTLY the tokens an uninterrupted run would."""
    _, model, params = small_model
    prompt = [5, 9, 2, 7, 1]
    r_base = _req(prompt, n=10)
    eng = _mk_engine(model, params)
    eng.admit(r_base)
    while not r_base.finished():
        eng.step()

    r_evict = _req(prompt, n=10)
    eng2 = _mk_engine(model, params)
    eng2.admit(r_evict)
    eng2.step(); eng2.step(); eng2.step()
    ev = eng2.evict_request(r_evict.req_id)
    assert ev is r_evict and r_evict.snapshot is not None
    assert eng2.num_active() == 0
    eng2.admit(r_evict)          # resume from snapshot (no prefill)
    assert eng2.stats.resumes == 1
    while not r_evict.finished():
        eng2.step()
    assert r_evict.output_tokens == r_base.output_tokens


def test_model_swap_flushes_and_serves(small_model):
    cfg, model, params = small_model
    model2 = build_model(ARCHITECTURES["h2o-danube-1.8b"].reduced(num_layers=2, d_model=128))
    params2 = model2.init(jax.random.key(1))
    eng = _mk_engine(model, params)
    r1 = _req([1, 2, 3], n=20, model="m1")
    eng.admit(r1)
    eng.step()
    evicted = eng.swap_model(model2, params2, "m2")
    assert [e.req_id for e in evicted] == [r1.req_id]
    assert eng.model_name == "m2" and eng.stats.model_swaps == 1
    r2 = _req([4, 5, 6], n=4, model="m2")
    eng.admit(r2)
    while not r2.finished():
        eng.step()
    assert len(r2.output_tokens) == 4


def test_oom_preemption(small_model):
    """KV-block exhaustion preempts instead of crashing (vLLM semantics)."""
    _, model, params = small_model
    eng = _mk_engine(model, params, kv_blocks=3, block_size=4)  # 12 tokens
    r1 = _req([1, 2, 3], n=30)
    r2 = _req([4, 5, 6], n=30)
    assert eng.admit(r1)
    # r2 can't fit alongside within watermark
    admitted2 = eng.admit(r2)
    for _ in range(30):
        eng.step()
        if eng.stats.preemptions > 0 or (r1.finished() and not admitted2):
            break
    assert eng.stats.preemptions >= 1 or not admitted2


def test_max_seq_len_emits_final_token(small_model):
    """The capacity finish must not fire a step early: a slot at
    lengths == max_seq_len - 1 has one legal decode step left (its KV write
    lands in the last cache slot) and that step's token must be emitted.
    Total generated tokens == max_seq_len - prompt_len + 1 (the prefill
    token + one per decode step + the final token that needs no KV slot)."""
    _, model, params = small_model
    M, P = 16, 5
    for chunk in (0, 8):   # legacy and chunked prefill paths agree
        eng = _mk_engine(model, params, max_seq_len=M,
                         prefill_chunk_tokens=chunk)
        r = _req(list(range(P)), n=100)      # max_new never binds
        assert eng.admit(r)
        for _ in range(2 * M):
            if r.finished():
                break
            eng.step()
        assert r.finished()
        assert len(r.output_tokens) == r.generated == M - P + 1
        assert eng.block_mgr.used_blocks == 0 and eng.num_active() == 0


def test_preemption_keeps_just_produced_token(small_model):
    """An append_token-failure preemption snapshots AFTER recording the
    decode step's token: output_tokens/generated/length stay consistent and
    the resumed request completes with the deterministic token stream."""
    _, model, params = small_model
    prompt = [1, 2, 3]
    base = _mk_engine(model, params)
    r_base = _req(prompt, n=12)
    assert base.admit(r_base)
    while not r_base.finished():
        base.step()

    eng = _mk_engine(model, params, kv_blocks=3, block_size=4, max_slots=1)
    r = _req(prompt, n=12)                   # 12 tokens of KV: must preempt
    assert eng.admit(r)
    for _ in range(15):
        eng.step()
        if eng.stats.preemptions:
            break
    assert eng.stats.preemptions == 1 and r.snapshot is not None
    assert len(r.output_tokens) == r.generated > 0
    # the token produced by the decode step that hit the OOM is in BOTH the
    # output stream and the snapshot's length accounting
    assert r.snapshot["length"] == r.prompt_len + r.generated - 1
    # drain capacity is permanently short for this request; verify the
    # kept prefix matches the uninterrupted run instead of resuming
    assert r.output_tokens == r_base.output_tokens[:len(r.output_tokens)]


def test_ttft_and_completion_recorded(small_model):
    _, model, params = small_model
    eng = _mk_engine(model, params)
    r = _req([1, 2, 3, 4], n=3)
    eng.admit(r)
    while not r.finished():
        eng.step()
    assert r.first_token_time is not None
    assert r.completion_time >= r.first_token_time
    assert r.ttft() is not None and r.ttft() >= 0
