"""End-to-end behaviour tests for the reproduced system (QLM, SoCC'24).

The headline claims, executed on the discrete-event cluster (calibrated
profiles) and cross-checked against the real-engine stack in
test_qlm_integration.py.
"""
import pytest

from repro.core.policies import make_policy
from repro.data.workload import workload_a, workload_b
from repro.sim import ClusterSimulator, profiles_for

WB_MODELS = ["mistral-7b-ft", "llama-70b-ft1", "vicuna-13b-ft",
             "llama-70b-ft2", "vicuna-13b-ft2"]


def test_paper_headline_multi_model():
    """QLM vs vLLM on W_B: throughput gain in the paper's 'up to 3-4x'
    regime and SLO attainment gap in the 40-90% band."""
    res = {}
    for policy in ("vllm", "qlm"):
        reqs = workload_b(arrival_rate=25, n_requests=500, seed=11)
        sim = ClusterSimulator([profiles_for("a100", WB_MODELS)
                                for _ in range(4)], policy)
        res[policy] = sim.run(reqs)
    gain = res["qlm"]["throughput_rps"] / max(res["vllm"]["throughput_rps"], 1e-9)
    slo_gap = res["qlm"]["slo_attainment"] - res["vllm"]["slo_attainment"]
    assert gain > 2.0, gain
    assert slo_gap > 0.2, slo_gap


def test_paper_headline_single_model_overload_recovers_with_rate():
    """Fig. 10: at low arrival rate every SLO is met; at overload nobody
    wins; QLM dominates in between."""
    def slo_at(rate, policy):
        reqs = workload_a(arrival_rate=rate, n_requests=300, seed=12)
        sim = ClusterSimulator([profiles_for("a100", ["vicuna-13b"])
                                for _ in range(2)], policy)
        return sim.run(reqs)["slo_attainment"]

    assert slo_at(5, "qlm") > 0.95
    mid_q, mid_v = slo_at(60, "qlm"), slo_at(60, "vllm")
    assert mid_q >= mid_v


def test_all_four_policies_available():
    for name in ("vllm", "edf", "shepherd", "qlm"):
        p = make_policy(name)
        assert p.traits.name == name
