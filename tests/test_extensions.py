"""§9 extensions: strict priorities, admission control, scale-up search,
ITL tracking."""
import numpy as np
import pytest

from repro.core.autoscale import AdmissionController, find_min_instances
from repro.core.global_scheduler import InstanceInfo
from repro.core.priority import PriorityScheduler
from repro.core.request import Request, make_request
from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile, RWTEstimator
from repro.core.virtual_queue import VirtualQueue

HW = HardwareProfile(prefill_time=0.1, decode_per_token=0.04,
                     inefficiency=1.2, token_capacity=60_000, swap_time=2.0)


def _group(model, slo, priority=0, n=4):
    g = RequestGroup(model=model, slo=slo)
    for i in range(n):
        r = make_request(list(range(20)), model, "batch1", arrival_time=0.0)
        r.slo = slo
        r.priority = priority
        g.add(r)
    return g


def test_priority_scheduler_orders_levels_strictly():
    vq = VirtualQueue(0)
    inst = InstanceInfo(0, {"m": HW}, "m", vq)
    # low-priority group has the TIGHTER deadline: plain EDF would put it
    # first, strict priority must not.
    g_low = _group("m", slo=5.0, priority=1)
    g_high = _group("m", slo=500.0, priority=0)
    sched = PriorityScheduler()
    sched.schedule([g_low, g_high], [inst], now=0.0)
    order = [g.group_id for g in vq.groups]
    assert order.index(g_high.group_id) < order.index(g_low.group_id)


def test_priority_scheduler_optimizes_within_level():
    vq = VirtualQueue(0)
    inst = InstanceInfo(0, {"a": HW, "b": HW}, "a", vq)
    # same priority, interleaved models: solver should group same-model
    gs = [_group("a", 100.0), _group("b", 102.0), _group("a", 104.0),
          _group("b", 106.0)]
    sched = PriorityScheduler(exact_threshold=7)
    sched.schedule(gs, [inst], now=0.0)
    ms = vq.models_in_order()
    switches = sum(1 for i in range(1, len(ms)) if ms[i] != ms[i - 1])
    assert switches <= 2  # EDF interleave would be 3


def test_admission_controller_rejects_when_drain_exceeds_bound():
    ac = AdmissionController(RWTEstimator(), HW, max_drain_s=10.0)
    r = make_request(list(range(20)), "m", "interactive")
    assert ac.admit(r, queue_pending_requests=0)
    assert not ac.admit(r, queue_pending_requests=100_000)
    assert len(ac.rejected) == 1


def test_find_min_instances_binary_search():
    calls = []

    def run_with_n(n):
        calls.append(n)
        return {"slo_attainment": 1.0 if n >= 5 else 0.5}

    res = find_min_instances(run_with_n, slo_target=0.9, lo=1, hi=16)
    assert res["min_instances"] == 5
    assert len(calls) <= 6  # logarithmic


def test_find_min_instances_infeasible():
    res = find_min_instances(lambda n: {"slo_attainment": 0.1},
                             slo_target=0.9, lo=1, hi=4)
    assert res["min_instances"] is None


def test_itl_tracking():
    r = Request(prompt_tokens=[1, 2], model="m", slo=10.0)
    assert r.itl() is None
    r.first_token_time = 1.0
    r.completion_time = 3.0
    r.generated = 5
    assert r.itl() == pytest.approx(0.5)


def test_sim_reports_itl():
    from repro.data.workload import workload_a
    from repro.sim import ClusterSimulator, profiles_for
    reqs = workload_a(arrival_rate=5, n_requests=60, seed=0)
    sim = ClusterSimulator([profiles_for("a100", ["vicuna-13b"])], "qlm")
    m = sim.run(reqs)
    # ITL ≈ decode_per_token (0.04) + admission-interleave overhead
    assert 0.03 <= m["mean_itl"] <= 0.12, m["mean_itl"]
