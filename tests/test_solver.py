"""Global-scheduler MILP solver: exactness, invariants, scaling."""
import random

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.core.solver import (GroupSpec, InstanceSpec, branch_and_bound,
                               brute_force, evaluate, local_search, solve)


def _random_instance(rng, n, G, models=("A", "B", "C")):
    instances = [InstanceSpec(i, rng.choice(list(models) + [None]),
                              {m: rng.uniform(1, 5) for m in models})
                 for i in range(G)]
    groups = [GroupSpec(j, rng.choice(models), rng.uniform(1, 30),
                        {i: rng.uniform(0.5, 10) for i in range(G)})
              for j in range(n)]
    return groups, instances


@pytest.mark.parametrize("seed", range(12))
def test_branch_and_bound_is_exact(seed):
    rng = random.Random(seed)
    groups, instances = _random_instance(rng, rng.randint(1, 5), rng.randint(1, 3))
    bf = brute_force(groups, instances)
    bb = branch_and_bound(groups, instances)
    assert abs(bf.violation - bb.violation) < 1e-9
    assert bb.total_penalty <= bf.total_penalty + 1e-9


@pytest.mark.parametrize("seed", range(8))
def test_local_search_never_beats_exact(seed):
    rng = random.Random(100 + seed)
    groups, instances = _random_instance(rng, rng.randint(2, 5), rng.randint(1, 3))
    bf = brute_force(groups, instances)
    ls = local_search(groups, instances, seed=seed)
    assert ls.violation >= bf.violation - 1e-9


def test_assignment_is_partition():
    rng = random.Random(7)
    groups, instances = _random_instance(rng, 30, 4)
    sol = solve(groups, instances)
    flat = [g for q in sol.assignment for g in q]
    assert sorted(flat) == list(range(len(groups)))  # Eq. 6


def test_feasible_iff_zero_violation():
    inst = [InstanceSpec(0, "A", {"A": 1.0})]
    groups = [GroupSpec(0, "A", slo=100.0, drain_time={0: 1.0})]
    sol = solve(groups, inst)
    assert sol.feasible and sol.violation == 0.0
    groups = [GroupSpec(0, "A", slo=0.5, drain_time={0: 1.0})]
    sol = solve(groups, inst)
    assert not sol.feasible and sol.violation > 0


def test_swap_aware_grouping_beats_edf_interleaving():
    """Insight #3: same-model groups placed together avoid swap thrash."""
    S = 10.0
    inst = [InstanceSpec(0, "A", {"A": S, "B": S})]
    # deadlines interleave models; EDF order A,B,A,B costs 3 swaps and
    # finishes at 5,20,35,50 => violates the last deadline (43); the
    # grouped order A,A,B,B finishes at 5,10,25,30 => all met.
    groups = [
        GroupSpec(0, "A", slo=40.0, drain_time={0: 5.0}),
        GroupSpec(1, "B", slo=41.0, drain_time={0: 5.0}),
        GroupSpec(2, "A", slo=42.0, drain_time={0: 5.0}),
        GroupSpec(3, "B", slo=43.0, drain_time={0: 5.0}),
    ]
    edf_assign = [[0, 1, 2, 3]]
    v_edf, _ = evaluate(edf_assign, groups, inst)
    sol = solve(groups, inst, exact_threshold=7)
    assert sol.violation < v_edf  # solver finds the swap-avoiding order


def test_heterogeneity_prefers_fast_instance():
    """Design Principle #3: groups land on the device that drains faster."""
    inst = [InstanceSpec(0, "A", {"A": 0.0}),   # fast (A100)
            InstanceSpec(1, "A", {"A": 0.0})]   # slow (A10)
    groups = [GroupSpec(j, "A", slo=10.0,
                        drain_time={0: 2.0, 1: 6.0}) for j in range(4)]
    sol = solve(groups, inst, exact_threshold=7)
    n_fast = len(sol.assignment[0])
    n_slow = len(sol.assignment[1])
    assert n_fast > n_slow  # RWT-profiled imbalance respected


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 12), G=st.integers(1, 5))
def test_solver_invariants(seed, n, G):
    rng = random.Random(seed)
    groups, instances = _random_instance(rng, n, G)
    sol = solve(groups, instances, seed=seed)
    flat = sorted(g for q in sol.assignment for g in q)
    assert flat == list(range(n))
    v, p = evaluate(sol.assignment, groups, instances)
    assert abs(v - sol.violation) < 1e-9
    assert sol.feasible == (sol.violation <= 1e-9)
    assert sol.violation >= 0


def test_scales_to_hundreds_of_groups():
    import time
    rng = random.Random(0)
    groups, instances = _random_instance(rng, 300, 8)
    t0 = time.monotonic()
    sol = solve(groups, instances)
    assert time.monotonic() - t0 < 5.0
    assert sorted(g for q in sol.assignment for g in q) == list(range(300))
