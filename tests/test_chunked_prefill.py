"""Chunked, length-bucketed prefill in the real engine: edge cases, chunk
interleaving fairness, eviction-resume of partially-prefilled requests,
chunk-granular KV allocation, and pallas-vs-xla backend parity."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.request import Request
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.serving.kv_cache import BlockManager


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def swa_model():
    cfg = ARCHITECTURES["h2o-danube-1.8b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_engine(model, params, **kw):
    cfg = EngineConfig(**{"max_slots": 4, "max_seq_len": 64, **kw})
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def _req(prompt, n=8):
    return Request(prompt_tokens=list(prompt), model="m1", slo=1e9,
                   max_new_tokens=n)


def _run_to_completion(eng, reqs, max_steps=200):
    for _ in range(max_steps):
        eng.step()
        if all(r.finished() for r in reqs):
            return
    raise AssertionError("requests did not finish")


def _legacy_tokens(model, params, prompt, n, **kw):
    """Reference: the single-shot (chunking disabled) prefill path."""
    eng = _mk_engine(model, params, prefill_chunk_tokens=0, **kw)
    r = _req(prompt, n=n)
    assert eng.admit(r)
    _run_to_completion(eng, [r])
    return r.output_tokens


# ---------------------------------------------------------------------------
# chunk-granular block allocation
# ---------------------------------------------------------------------------

def test_block_manager_extend():
    bm = BlockManager(num_blocks=10, block_size=4)
    bm.allocate(1, 3)                  # 1 block
    assert bm.extend(1, 3)             # no-op (not shrinking either)
    assert bm.free_blocks == 9
    assert bm.extend(1, 9)             # grow to 3 blocks
    assert bm.free_blocks == 7 and bm.seq_tokens(1) == 9
    assert not bm.extend(1, 100)       # 25 blocks > capacity: refused
    assert bm.seq_tokens(1) == 9       # unchanged on failure
    bm.free(1)
    assert bm.free_blocks == 10


def test_admit_allocates_only_first_chunk(small_model):
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=8, block_size=4,
                     max_seq_len=64)
    r = _req(range(24), n=4)
    assert eng.admit(r)
    # only the first chunk (8 tokens = 2 blocks) is allocated at admission
    assert eng.block_mgr.seq_tokens(r.req_id) == 8
    eng.step()   # chunk 1 computed; chunk 2 not yet issued
    assert eng.block_mgr.seq_tokens(r.req_id) == 8
    eng.step()   # chunk 2 issued: allocation grows chunk-granularly
    assert eng.block_mgr.seq_tokens(r.req_id) == 16
    eng.step()   # final chunk: prompt + 1 slot for the first decode token
    assert eng.block_mgr.seq_tokens(r.req_id) >= 25
    _run_to_completion(eng, [r])
    assert eng.block_mgr.used_blocks == 0


# ---------------------------------------------------------------------------
# edge cases vs the single-shot reference path
# ---------------------------------------------------------------------------

def test_prompt_shorter_than_one_chunk(small_model):
    _, model, params = small_model
    prompt = [5, 9, 2]
    want = _legacy_tokens(model, params, prompt, n=6)
    eng = _mk_engine(model, params, prefill_chunk_tokens=16)
    r = _req(prompt, n=6)
    assert eng.admit(r)
    _run_to_completion(eng, [r])
    assert r.output_tokens == want


def test_prompt_exact_chunk_multiple(small_model):
    _, model, params = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 100, size=32).tolist()   # exactly 2 chunks of 16
    want = _legacy_tokens(model, params, prompt, n=5)
    eng = _mk_engine(model, params, prefill_chunk_tokens=16)
    r = _req(prompt, n=5)
    assert eng.admit(r)
    assert eng.prefilling_slots() == [0]
    eng.step()
    assert int(eng.prefill_pos[0]) == 16 and not r.output_tokens
    eng.step()
    assert r.output_tokens            # final chunk emitted the first token
    _run_to_completion(eng, [r])
    assert r.output_tokens == want


def test_first_token_completion_agrees_across_paths(small_model):
    """max_new_tokens=1 completes with exactly one token on BOTH the legacy
    single-shot path (finish check at admit) and the chunked path (finish
    check on the final chunk)."""
    _, model, params = small_model
    prompt = [5, 9, 2]
    outs = {}
    for chunk in (0, 16):
        eng = _mk_engine(model, params, prefill_chunk_tokens=chunk)
        r = _req(prompt, n=1)
        assert eng.admit(r)
        for _ in range(5):
            if r.finished():
                break
            eng.step()
        assert r.finished()
        assert eng.block_mgr.used_blocks == 0 and eng.num_active() == 0
        outs[chunk] = list(r.output_tokens)
    assert outs[0] == outs[16]
    assert len(outs[0]) == 1


def test_step_returns_admit_completed_requests(small_model):
    """A request that finishes INSIDE admit() (legacy path, max_new=1)
    must still appear in step()'s documented return value."""
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=0)
    r = _req([5, 9, 2], n=1)
    queue = [r]
    eng.pull_source = lambda: queue.pop(0) if queue else None
    done = eng.step()
    assert r.finished()
    assert done == [r]
    assert eng.completed == [r]


def test_direct_admit_completion_visible_without_step(small_model):
    """A direct admit() that completes instantly must land in
    engine.completed right away (a 'while num_active(): step()' drain loop
    never runs), and the next step() returns it exactly once."""
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=0)
    r = _req([5, 9, 2], n=1)
    assert eng.admit(r)
    assert r.finished() and eng.num_active() == 0
    assert eng.completed == [r]
    assert eng.step() == [r]          # returned once, not re-added
    assert eng.completed == [r]
    assert eng.step() == []


def test_failed_prefill_leaves_engine_clean(small_model):
    """An exception inside the single-shot prefill must not leave a corrupt
    half-admitted slot behind (no slot occupancy, no block allocation)."""
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=0)

    def boom(prompt, extras):
        raise RuntimeError("device OOM")

    eng._prefill_one = boom
    r = _req([1, 2, 3], n=4)
    with pytest.raises(RuntimeError):
        eng.admit(r)
    assert eng.num_active() == 0
    assert eng.block_mgr.used_blocks == 0
    assert not eng.block_mgr.has(r.req_id)
    eng.step()                       # engine still serviceable
    del eng._prefill_one             # restore the real method
    assert eng.admit(r)
    _run_to_completion(eng, [r])


def test_sliding_window_chunked_matches_single_shot(swa_model):
    """Rolling SWA cache: chunked prefill (incl. slot wrap for prompts past
    the window) must reproduce the single-shot tokens."""
    _, model, params = swa_model
    rng = np.random.default_rng(2)
    for plen in (20, 80):             # 80 > window (64): rolling wrap
        prompt = rng.integers(0, 100, size=plen).tolist()
        want = _legacy_tokens(model, params, prompt, n=4, max_seq_len=128)
        eng = _mk_engine(model, params, prefill_chunk_tokens=16,
                         max_seq_len=128)
        r = _req(prompt, n=4)
        assert eng.admit(r)
        _run_to_completion(eng, [r])
        assert r.output_tokens == want, plen


def test_batched_multi_request_prefill(small_model):
    """Several waiting prompts of different lengths prefill as ONE batched
    call per step (length-bucketed padding), and each still produces the
    single-shot tokens."""
    _, model, params = small_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 17, 30)]
    want = [_legacy_tokens(model, params, p, n=4) for p in prompts]
    eng = _mk_engine(model, params, prefill_chunk_tokens=16)
    reqs = [_req(p, n=4) for p in prompts]
    for r in reqs:
        assert eng.admit(r)
    assert len(eng.prefilling_slots()) == 3
    chunks0 = eng.stats.prefill_chunks
    eng.step()
    # one batched chunk round covered all three mid-prefill slots
    assert eng.stats.prefill_chunks == chunks0 + 1
    _run_to_completion(eng, reqs)
    for r, w in zip(reqs, want):
        assert r.output_tokens == w


def test_bucket_resolution():
    assert EngineConfig(prefill_chunk_tokens=128).resolved_buckets() == \
        (16, 32, 64, 128)
    assert EngineConfig(prefill_chunk_tokens=0).resolved_buckets() == ()
    assert EngineConfig(prefill_chunk_tokens=100).resolved_buckets() == \
        (16, 32, 64, 100)
    # custom buckets are completed up to the chunk size so padding never
    # falls back to exact (unbounded) lengths
    assert EngineConfig(prefill_buckets=(64, 8),
                        prefill_chunk_tokens=128).resolved_buckets() == \
        (8, 64, 128)
    assert EngineConfig(prefill_buckets=(8, 64),
                        prefill_chunk_tokens=32).resolved_buckets() == (8, 64)


def test_can_admit_accounts_for_owed_prefill_blocks(small_model):
    """Admission reserves only the first chunk, but can_admit must count the
    blocks still OWED to mid-prefill slots — two long prompts must not both
    pass the check when only one fits."""
    _, model, params = small_model
    # 16 blocks * 4 = 64 tokens of KV; each 40-token prompt needs 11 blocks
    eng = _mk_engine(model, params, prefill_chunk_tokens=8, block_size=4,
                     kv_blocks=16, max_seq_len=64, max_slots=2)
    r1 = _req(range(40), n=2)
    r2 = _req(range(40), n=2)
    assert eng.admit(r1)              # only 2 blocks allocated, 9 owed
    assert not eng.can_admit(r2)      # 11 + 9 owed > 15 free-above-watermark
    _run_to_completion(eng, [r1])
    assert len(r1.output_tokens) == 2
    assert eng.can_admit(r2)          # capacity back after r1 drained


def test_swa_chunk_clamped_to_window(swa_model):
    """A configured chunk larger than the SWA window must be clamped: one
    chunk writing the same rolling slot twice would scatter
    nondeterministically.  Tokens must still match the single-shot path."""
    _, model, params = swa_model     # reduced window = 64
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 100, size=100).tolist()
    want = _legacy_tokens(model, params, prompt, n=4, max_seq_len=256)
    eng = _mk_engine(model, params, prefill_chunk_tokens=128, max_seq_len=256)
    assert eng._chunk_quantum() == 64
    r = _req(prompt, n=4)
    assert eng.admit(r)
    eng.step()
    assert int(eng.prefill_pos[0]) == 64   # clamped quantum
    _run_to_completion(eng, [r])
    assert r.output_tokens == want


def test_preempted_request_becomes_repullable(small_model):
    """Engine-internal OOM preemption resets _in_flight (simulator
    _evict_seq parity) so a virtual-queue owner can re-pull the request."""
    _, model, params = small_model
    eng = _mk_engine(model, params, kv_blocks=3, block_size=4, max_slots=2)
    r1 = _req([1, 2, 3], n=30)
    r2 = _req([4, 5, 6], n=30)
    assert eng.admit(r1)
    eng.admit(r2)
    r1._in_flight = r2._in_flight = True
    for _ in range(30):
        eng.step()
        if eng.stats.preemptions:
            break
    assert eng.stats.preemptions >= 1
    preempted = [r for r in (r1, r2) if r.snapshot is not None]
    assert preempted and all(not r._in_flight for r in preempted)


# ---------------------------------------------------------------------------
# eviction-resume of a partially-prefilled request
# ---------------------------------------------------------------------------

def test_evict_resume_mid_prefill(small_model):
    _, model, params = small_model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, 100, size=24).tolist()

    base = _mk_engine(model, params, prefill_chunk_tokens=8)
    r_base = _req(prompt, n=8)
    assert base.admit(r_base)
    _run_to_completion(base, [r_base])

    eng = _mk_engine(model, params, prefill_chunk_tokens=8)
    r = _req(prompt, n=8)
    assert eng.admit(r)
    eng.step()                                   # one chunk done (8/24)
    assert int(eng.prefill_pos[0]) == 8
    ev = eng.evict_request(r.req_id)
    assert ev is r and r.snapshot is not None
    assert r.snapshot["prefill_pos"] == 8        # chunk progress snapshotted
    assert r.generated == 0                      # no token yet
    assert eng.block_mgr.used_blocks == 0

    assert eng.admit(r)                          # resume: no prefill recompute
    assert eng.stats.resumes == 1
    assert int(eng.prefill_pos[0]) == 8          # continues from chunk 2
    _run_to_completion(eng, [r])
    assert r.output_tokens == r_base.output_tokens
    assert r.n_evictions == 1


def test_mid_prefill_snapshot_on_nonchunking_engine_recomputes(small_model):
    """A mid-prefill snapshot re-admitted to an engine that cannot chunk
    (prefill_chunk_tokens=0) must fall back to a full prefill recompute
    instead of spinning on zero-token chunk rounds."""
    _, model, params = small_model
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, 100, size=24).tolist()
    want = _legacy_tokens(model, params, prompt, n=6)

    eng = _mk_engine(model, params, prefill_chunk_tokens=8)
    r = _req(prompt, n=6)
    assert eng.admit(r)
    eng.step()
    eng.evict_request(r.req_id)
    assert r.snapshot["prefill_pos"] == 8

    other = _mk_engine(model, params, prefill_chunk_tokens=0)
    assert other.admit(r)
    assert other.stats.resumes == 0 and other.stats.prefills == 1
    _run_to_completion(other, [r])
    assert r.output_tokens == want


# ---------------------------------------------------------------------------
# interleaving fairness: decode keeps flowing while a long prompt prefills
# ---------------------------------------------------------------------------

def test_decode_interleaves_with_prefill_chunks(small_model):
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=16, max_seq_len=128)
    rng = np.random.default_rng(5)

    short = _req(rng.integers(0, 100, size=4).tolist(), n=40)
    assert eng.admit(short)
    eng.step()
    assert short.output_tokens                   # short req is decoding

    long_req = _req(rng.integers(0, 100, size=48).tolist(), n=4)
    assert eng.admit(long_req)                   # 3 chunks of 16
    tokens_between_chunks = []
    while eng.prefilling_slots():
        before = len(short.output_tokens)
        pos_before = int(eng.prefill_pos[eng.prefilling_slots()[0]])
        eng.step()
        gained = len(short.output_tokens) - before
        tokens_between_chunks.append(gained)
        assert int(eng.prefill_pos[1]) > pos_before or long_req.output_tokens
    # the long prompt took several chunk rounds, and the active decode slot
    # produced >= 1 token during EVERY one of them (the chunking papers'
    # core co-scheduling property)
    assert len(tokens_between_chunks) == 3
    assert all(g >= 1 for g in tokens_between_chunks)
    assert long_req.output_tokens                # long req got its first token
    _run_to_completion(eng, [short, long_req])


def test_mid_prefill_slot_state_consistent(small_model):
    """Mid-prefill slots report lengths == prefill_pos (< prompt_len) and
    are excluded from decode; decode-ready slots keep the old invariant."""
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=8)
    r = _req(list(range(20)), n=4)
    assert eng.admit(r)
    eng.step()
    (slot,) = eng.prefilling_slots()
    assert int(eng.lengths[slot]) == int(eng.prefill_pos[slot]) == 8
    assert eng.decode_slots() == []
    _run_to_completion(eng, [r])


# ---------------------------------------------------------------------------
# attention backend selection
# ---------------------------------------------------------------------------

def test_pallas_backend_matches_xla_tokens():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 100, size=6).tolist() for _ in range(2)]

    outs = {}
    for backend in ("xla", "pallas"):
        eng = _mk_engine(model, params, prefill_chunk_tokens=16,
                         attention_backend=backend, max_slots=2)
        reqs = [_req(p, n=4) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        _run_to_completion(eng, reqs)
        outs[backend] = [r.output_tokens for r in reqs]
    # interpret-mode Pallas decode must match the XLA path token-for-token
    assert outs["pallas"] == outs["xla"]


def test_backend_override_is_bidirectional():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    pallas_model = build_model(dataclasses.replace(cfg, use_pallas_attention=True))
    params = model.init(jax.random.key(0))
    # "pallas" forces the kernels on; "xla" forces them off; None respects
    # whatever the model config says
    assert _mk_engine(model, params,
                      attention_backend="pallas").model.cfg.use_pallas_attention
    assert not _mk_engine(pallas_model, params,
                          attention_backend="xla").model.cfg.use_pallas_attention
    assert _mk_engine(pallas_model, params).model is pallas_model
    assert _mk_engine(model, params).model is model
    with pytest.raises(ValueError):
        _mk_engine(model, params, attention_backend="cuda")


# ---------------------------------------------------------------------------
# RWT prefill-term awareness
# ---------------------------------------------------------------------------

def test_hw_profile_prefill_seconds_chunk_aware():
    from repro.core.rwt_estimator import (HardwareProfile, RWTEstimator,
                                          WorkloadProfile)
    hw_lump = HardwareProfile(prefill_time=0.2, decode_per_token=0.04,
                              inefficiency=1.2, token_capacity=60_000,
                              model_max_tokens=512)
    hw_chunk = dataclasses.replace(hw_lump, prefill_chunk_tokens=256)
    # no prompt length => the paper's constant P (legacy behavior unchanged)
    assert hw_lump.prefill_seconds() == hw_chunk.prefill_seconds() == 0.2
    # token-scaled: P is per 1k prompt tokens (simulator accounting)
    assert hw_lump.prefill_seconds(1024) == pytest.approx(0.2)
    assert hw_lump.prefill_seconds(2048) == pytest.approx(0.4)
    # chunked: + one interleaved decode iteration per chunk
    assert hw_chunk.prefill_seconds(1024) == pytest.approx(0.2 + 4 * 0.04)

    est = RWTEstimator()
    wl = WorkloadProfile(mu_input=1024, sigma_input=1.0,
                         mu_output=128, sigma_output=1.0)
    base = est.request_completion(3, wl, hw_chunk)
    aware = est.request_completion(3, wl, hw_chunk, prompt_tokens=wl.mu_input)
    assert aware.mean == pytest.approx(
        base.mean - hw_chunk.prefill_time + hw_chunk.prefill_seconds(1024))
    assert est.group_first_token_time(0, wl, hw_chunk, prompt_tokens=1024) \
        == pytest.approx(hw_chunk.prefill_seconds(1024))
    # group_drain_time (the global scheduler's term) honors it too
    d0 = est.group_drain_time(4, wl, hw_chunk)
    d1 = est.group_drain_time(4, wl, hw_chunk, prompt_tokens=wl.mu_input)
    assert d1.mean == pytest.approx(
        d0.mean - hw_chunk.prefill_time + hw_chunk.prefill_seconds(1024))


def test_sim_chunked_prefill_accounting():
    """Simulator mirror of the engine: no decode charge while every running
    sequence is mid-prefill, and mid-prefill evictions resume from their
    chunk progress instead of recomputing the whole prompt."""
    from repro.core.policies import make_policy
    from repro.core.request import make_request
    from repro.core.request_group import RequestGroup
    from repro.core.rwt_estimator import HardwareProfile
    from repro.sim.simulator import SimInstance

    traits = dataclasses.replace(make_policy("qlm").traits,
                                 prefill_chunk_tokens=16)
    hw = HardwareProfile(prefill_time=1.024, decode_per_token=0.5,
                         inefficiency=1.0, token_capacity=4096,
                         swap_time=2.0, model_max_tokens=64)
    inst = SimInstance(0, {"m": hw}, traits)
    req = make_request(list(range(64)), "m", "batch1", max_new_tokens=4)
    req.true_output_tokens = 4
    g = RequestGroup(model="m", slo=60.0)
    g.add(req)
    inst.vq.set_order([g])

    end, done = inst.iteration(0.0)
    (seq,) = inst.running
    assert seq.prefill_remaining == 48          # one 16-token chunk done
    assert req.generated == 0                   # no decode token yet
    # cold load (2.0) + chunk prefill (1.024 * 16/1024) — and NO 0.5 decode
    # charge, because the engine's decode round is a no-op here
    assert end == pytest.approx(2.0 + 1.024 * 16 / 1024)

    inst._evict_seq(seq)
    assert req._prefill_done == 16
    end2, _ = inst.iteration(end)
    (seq2,) = inst.running
    # resumed from the snapshot: only 48 - 16 tokens left, not 64 - 16
    assert seq2.prefill_remaining == 32

    end3, _ = inst.iteration(end2)
    end4, _ = inst.iteration(end3)
    # the final chunk and the first decode token share one quantum (engine
    # parity: the chunk round precedes the decode round in the same step)
    assert seq2.prefill_remaining == 0 and req.generated == 1
    assert end4 - end3 == pytest.approx(1.024 * 16 / 1024 + 0.5)


def test_cluster_sim_propagates_chunking_into_profiles():
    """Chunked execution (PolicyTraits) must also flip the RWT hardware
    model (HardwareProfile.prefill_chunk_tokens) so drain estimates match
    what the instances actually do."""
    from repro.sim import ClusterSimulator, profiles_for
    sim = ClusterSimulator([profiles_for("a100", ["vicuna-13b"])], "qlm",
                           traits_override={"prefill_chunk_tokens": 256})
    assert sim.instances[0].hw_by_model["vicuna-13b"].prefill_chunk_tokens == 256
    sim2 = ClusterSimulator([profiles_for("a100", ["vicuna-13b"])], "qlm")
    assert sim2.instances[0].hw_by_model["vicuna-13b"].prefill_chunk_tokens is None


def test_calibrate_from_engine_propagates_chunking(small_model):
    from repro.sim.profiles import calibrate_from_engine
    _, model, params = small_model
    eng = _mk_engine(model, params, prefill_chunk_tokens=16)
    hw = calibrate_from_engine(eng, token_capacity=256)
    assert hw.prefill_chunk_tokens == 16
    eng2 = _mk_engine(model, params, prefill_chunk_tokens=0)
    hw2 = calibrate_from_engine(eng2, token_capacity=256)
    assert hw2.prefill_chunk_tokens is None


# ---------------------------------------------------------------------------
# kv-quant cache works through the chunked path
# ---------------------------------------------------------------------------

def test_kv_quant_chunked_prefill_smoke():
    cfg = dataclasses.replace(
        ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64),
        kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = _mk_engine(model, params, prefill_chunk_tokens=8)
    r = _req(list(range(20)), n=4)
    assert eng.admit(r)
    _run_to_completion(eng, [r])
    assert len(r.output_tokens) == 4
