"""BlockManager property tests: random allocate/extend/append_token/free
interleavings never double-assign a block and always conserve
``free_blocks + used_blocks == num_blocks`` (the invariants the paged KV
pool's physical page reuse depends on) — and the incrementally-maintained
slot table always equals a from-scratch rebuild."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import BlockManager


def _check_invariants(bm: BlockManager) -> None:
    assert bm.free_blocks + bm.used_blocks == bm.num_blocks
    for s in list(bm._seqs):
        alloc = bm._seqs[s]
        # table length tracks blocks_needed exactly (append_token reserves
        # the next block right when num_tokens crosses a boundary)
        assert len(alloc.block_table) == bm.blocks_needed(alloc.num_tokens) \
            or alloc.num_tokens % bm.block_size == 0
        assert alloc.num_tokens <= len(alloc.block_table) * bm.block_size
    # no block is double-owned, none both owned and free
    owned = [b for s in bm._seqs.values() for b in s.block_table]
    assert len(owned) == len(set(owned))
    assert not (set(owned) & set(bm._free))
    assert all(0 <= b < bm.num_blocks for b in owned)


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "append", "free"]),
                          st.integers(0, 7), st.integers(1, 40)),
                max_size=80))
def test_accounting_invariants(ops):
    bm = BlockManager(num_blocks=16, block_size=4)
    for op, sid, ntok in ops:
        if op == "alloc" and not bm.has(sid):
            if bm.can_allocate(ntok):
                bm.allocate(sid, ntok)   # same bound: must never raise
        elif op == "extend" and bm.has(sid):
            before = bm.seq_tokens(sid)
            if not bm.extend(sid, ntok):
                assert bm.seq_tokens(sid) == before  # refusal mutates nothing
        elif op == "append" and bm.has(sid):
            bm.append_token(sid)
        elif op == "free":
            bm.free(sid)
        _check_invariants(bm)


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "append",
                                           "free", "reset"]),
                          st.integers(0, 7), st.integers(1, 40)),
                max_size=80))
def test_incremental_slot_table_matches_rebuild(ops):
    """The table BlockManager maintains in place on every
    allocate/extend/append_token/free (the engine's hot-loop block table)
    is always identical to rebuilding it from the per-sequence block
    tables — the invariant _device_block_table's version-gated upload
    relies on."""
    rows, width, bs = 8, 16, 4
    bm = BlockManager(num_blocks=24, block_size=bs)
    bm.attach_slot_table(rows, width)
    cap = width * bs                       # engine-enforced per-seq bound
    free_rows = set(range(rows))
    row_of = {}
    for op, sid, ntok in ops:
        version = bm.table_version
        mutated = False  # ops below that MUST bump the version
        if op == "alloc" and not bm.has(sid) and free_rows:
            if bm.can_allocate(min(ntok, cap)):
                bm.allocate(sid, min(ntok, cap))
                row_of[sid] = free_rows.pop()
                bm.bind_slot(sid, row_of[sid])
                mutated = True
        elif op == "extend" and bm.has(sid):
            before = len(bm.block_table(sid))
            bm.extend(sid, min(ntok, cap))
            mutated = len(bm.block_table(sid)) > before
        elif op == "append" and bm.has(sid) and bm.seq_tokens(sid) < cap:
            before = len(bm.block_table(sid))
            bm.append_token(sid)
            mutated = len(bm.block_table(sid)) > before
        elif op == "free" and bm.has(sid):
            bm.free(sid)
            free_rows.add(row_of.pop(sid))
            mutated = True
        elif op == "reset":
            bm.reset()
            free_rows = set(range(rows))
            row_of.clear()
            mutated = True
        want = np.full((rows, width), bm.num_blocks, np.int32)
        for s, r in row_of.items():
            blocks = bm.block_table(s)
            want[r, :len(blocks)] = blocks
        np.testing.assert_array_equal(bm.slot_table(), want)
        # a mutation that stopped bumping the version would make
        # _device_block_table serve a STALE device table — every
        # table-changing op above must move the counter
        if mutated:
            assert bm.table_version > version


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=10),
       st.integers(1, 8))
def test_free_restores_full_capacity(token_counts, block_size):
    bm = BlockManager(num_blocks=64, block_size=block_size)
    admitted = []
    for sid, n in enumerate(token_counts):
        if bm.can_allocate(n):
            bm.allocate(sid, n)
            admitted.append(sid)
        _check_invariants(bm)
    for sid in admitted:
        bm.free(sid)
    assert bm.free_blocks == bm.num_blocks and bm.tokens_allocated() == 0
