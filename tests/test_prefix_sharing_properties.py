"""BlockManager prefix-sharing property tests: random interleavings of
allocate / share_prefix / fork / extend / append_token / free /
evict_split / resume_pinned / release_pins / reset must conserve
refcounts (every block's refcount equals its appearances across live
sequence tables plus snapshot pins — the "sum of per-seq views == pool
usage" invariant), never free a block that is still referenced, never let
a freed-then-reused block appear in two live chains it doesn't belong to,
keep the prefix index pointing only at live blocks, and keep the
incrementally-maintained slot table identical to a from-scratch rebuild
(extends the PR 4 property test to the sharing ops).

The random walk runs twice: a seeded plain-pytest version (always on, so
tier-1 exercises the invariants even without the optional ``hypothesis``
dep) and a hypothesis-driven version that explores far more interleavings
in CI.
"""
import numpy as np
import pytest

from repro.serving.kv_cache import BlockManager, OutOfBlocksError

ROWS, WIDTH, BS, NUM_BLOCKS = 8, 16, 4, 24
CAP = WIDTH * BS  # engine-enforced per-seq token bound
# three prompt "families": sequences in the same family share a prefix
# stream, so matches/shares actually happen
FAMILIES = [
    [100 + i for i in range(CAP)],
    [200 + i for i in range(CAP)],
    [100 + i for i in range(2 * BS)] + [300 + i for i in range(CAP - 2 * BS)],
]

OPS = ("alloc", "share", "fork", "extend", "append", "free",
       "evict", "resume", "release", "reset")


class _Harness:
    """Drives a BlockManager the way the engine does (slot binding,
    post-compute registration, COW-op draining) and mirrors enough state
    to check the conservation invariants from the outside."""

    def __init__(self):
        self.bm = BlockManager(num_blocks=NUM_BLOCKS, block_size=BS)
        self.bm.attach_slot_table(ROWS, WIDTH)
        self.tokens = {}        # sid -> prompt stream (full family slice)
        self.free_rows = set(range(ROWS))
        self.row_of = {}
        # sid -> (pinned_blocks, num_tokens, epoch) for evicted sequences
        self.snapshots = {}
        self.drained_cow = []

    # -- engine-mimicking op wrappers ----------------------------------
    def alloc(self, sid, fam, ntok):
        if self.bm.has(sid) or sid in self.snapshots or not self.free_rows:
            return
        ntok = min(ntok, CAP)
        if not self.bm.can_allocate(ntok):
            return
        self.bm.allocate(sid, ntok)
        self.tokens[sid] = FAMILIES[fam]
        self._bind(sid)
        self._register(sid)

    def share(self, sid, fam, ntok):
        if self.bm.has(sid) or sid in self.snapshots or not self.free_rows:
            return
        toks = FAMILIES[fam]
        matched = self.bm.match_prefix(toks[:min(ntok, CAP)])
        if not matched:
            return
        ntok = max(min(ntok, CAP), len(matched) * BS)
        if not self.bm.can_allocate(ntok, shared_blocks=len(matched)):
            return
        self.bm.share_prefix(sid, ntok, matched)
        self.tokens[sid] = toks
        self._bind(sid)
        self._register(sid)

    def fork(self, src, sid):
        if not self.bm.has(src) or self.bm.has(sid) \
                or sid in self.snapshots or not self.free_rows:
            return
        try:
            self.bm.fork(src, sid)
        except OutOfBlocksError:
            return
        self.tokens[sid] = self.tokens[src]
        self._bind(sid)

    def extend(self, sid, ntok):
        if not self.bm.has(sid):
            return
        before = self.bm.seq_tokens(sid)
        if not self.bm.extend(sid, min(ntok, CAP)):
            assert self.bm.seq_tokens(sid) == before  # refusal mutates nothing
        self._register(sid)

    def append(self, sid):
        if self.bm.has(sid) and self.bm.seq_tokens(sid) < CAP:
            self.bm.append_token(sid)
            self._register(sid)

    def free(self, sid):
        if self.bm.has(sid):
            self.bm.free(sid)
            self._unbind(sid)

    def evict(self, sid):
        if not self.bm.has(sid):
            return
        ntok = self.bm.seq_tokens(sid)
        pinned, private = self.bm.evict_split(sid)
        assert pinned + private  # the whole chain was split, none dropped
        self.snapshots[sid] = (pinned, ntok, self.bm.epoch)
        self._unbind(sid)

    def resume(self, sid):
        if sid not in self.snapshots or self.bm.has(sid) \
                or not self.free_rows:
            return
        pinned, ntok, epoch = self.snapshots[sid]
        if epoch != self.bm.epoch:      # pool reset while evicted: dead pins
            del self.snapshots[sid]
            return
        if not self.bm.can_allocate(ntok, shared_blocks=len(pinned)):
            return
        self.bm.resume_pinned(sid, pinned, ntok)
        del self.snapshots[sid]
        self._bind(sid)

    def release(self, sid):
        if sid not in self.snapshots:
            return
        pinned, _, epoch = self.snapshots.pop(sid)
        self.bm.release_pins(pinned, epoch)

    def reset(self):
        self.bm.reset()
        # outstanding snapshot pins died with the epoch (release_pins on a
        # stale epoch must no-op; exercised by later "release" ops)
        self.tokens.clear()
        self.free_rows = set(range(ROWS))
        self.row_of.clear()

    # -- helpers -------------------------------------------------------
    def _bind(self, sid):
        self.row_of[sid] = self.free_rows.pop()
        self.bm.bind_slot(sid, self.row_of[sid])

    def _unbind(self, sid):
        self.free_rows.add(self.row_of.pop(sid))

    def _register(self, sid):
        # the engine registers full blocks as their chunks complete;
        # registering up to the current allocation is the steady state
        self.bm.register_prefix(sid, self.tokens[sid],
                                self.bm.seq_tokens(sid))

    def step(self, op, sid, ntok, fam):
        version = self.bm.table_version
        getattr(self, op)(*{
            "alloc": (sid, fam, ntok), "share": (sid, fam, ntok),
            "fork": ((sid + 1) % 8, sid), "extend": (sid, ntok),
            "append": (sid,), "free": (sid,), "evict": (sid,),
            "resume": (sid,), "release": (sid,), "reset": (),
        }[op])
        self.drained_cow.extend(self.bm.take_cow_ops())
        self.check(version)

    # -- invariants ----------------------------------------------------
    def check(self, version_before=None):
        bm = self.bm
        assert bm.free_blocks + bm.used_blocks == bm.num_blocks
        # refcount conservation: every block's refcount equals its
        # appearances across live sequence tables plus snapshot pins —
        # so freeing can never orphan or double-own a block, and a
        # freed-then-reused block cannot linger in a stale chain
        want_ref = np.zeros(bm.num_blocks, np.int64)
        for s in bm._seqs.values():
            for b in s.block_table:
                want_ref[b] += 1
        for pinned, _, epoch in self.snapshots.values():
            if epoch == bm.epoch:
                for b in pinned:
                    want_ref[b] += 1
        np.testing.assert_array_equal(bm._ref, want_ref)
        # no block is freed while referenced / none both owned and free
        free = set(bm._free)
        assert all(want_ref[b] == 0 for b in free)
        assert all(want_ref[b] >= 1
                   for b in range(bm.num_blocks) if b not in free)
        assert len(bm._free) == len(free)  # no duplicates on the free list
        # per-seq table length tracks blocks_needed
        for s in bm._seqs.values():
            assert len(s.block_table) == bm.blocks_needed(s.num_tokens) \
                or s.num_tokens % bm.block_size == 0
            assert s.num_tokens <= len(s.block_table) * bm.block_size
        # prefix index only names live blocks, bijectively with _block_key
        for key, b in bm._index.items():
            assert want_ref[b] >= 1, (key, b)
            assert bm._block_key[b] == key
        for b, key in bm._block_key.items():
            assert bm._index[key] == b
        # shared chains agree on content: walking the index reproduces
        # each live sequence's own leading blocks
        for sid, s in bm._seqs.items():
            matched = bm.match_prefix(self.tokens[sid][:s.num_tokens],
                                      max_tokens=s.num_tokens)
            upto = min(len(matched), s.registered)
            assert matched[:upto] == s.block_table[:upto], sid
        # incremental slot table == from-scratch rebuild
        want = np.full((ROWS, WIDTH), bm.num_blocks, np.int32)
        for sid, r in self.row_of.items():
            blocks = bm.block_table(sid)
            want[r, :len(blocks)] = blocks
        np.testing.assert_array_equal(bm.slot_table(), want)
        # drained COW ops never name a still-shared destination
        for _, dst in self.drained_cow[-4:]:
            assert dst < bm.num_blocks


def _run_walk(ops):
    h = _Harness()
    for op, sid, ntok, fam in ops:
        h.step(op, sid, ntok, fam)
    return h


def test_seeded_random_walk_conserves_refcounts():
    """Plain-pytest walk (no hypothesis needed): 60 seeded random op
    sequences of length 120 over 8 sequence ids and 3 prompt families."""
    rng = np.random.default_rng(0)
    for _ in range(60):
        ops = [(OPS[rng.integers(len(OPS))], int(rng.integers(8)),
                int(rng.integers(1, CAP + 1)), int(rng.integers(3)))
               for _ in range(120)]
        h = _run_walk(ops)
        # drain everything: full capacity must come back
        for sid in list(h.bm._seqs):
            h.free(sid)
        for sid in list(h.snapshots):
            h.release(sid)
        h.check()
        assert h.bm.free_blocks == h.bm.num_blocks
        assert not h.bm._index and not h.bm._block_key and not h.bm._pins


def test_share_then_free_sharers_keeps_chain_correct():
    """Deterministic regression: A registers, B+C share, A frees — the
    chain must survive via B/C's refs and still be matchable; then B
    evicts (pinning the still-shared leading run; its privately-owned
    third block is released with the snapshot) and C frees — the pins
    alone must keep the shared run alive and matchable."""
    h = _Harness()
    h.alloc(0, 0, 3 * BS + 1)
    matched = h.bm.match_prefix(FAMILIES[0][:3 * BS + 1])
    assert len(matched) == 3
    h.share(1, 0, 3 * BS + 2)
    h.share(2, 0, 2 * BS + 1)
    assert [h.bm.ref_count(b) for b in matched] == [3, 3, 2]
    h.free(0)
    assert h.bm.match_prefix(FAMILIES[0][:3 * BS + 1]) == matched
    h.evict(1)
    # B's pin spans matched[:2] (still shared with C at evict time);
    # matched[2] was private to B by then -> released with the snapshot
    assert h.snapshots[1][0] == matched[:2]
    h.free(2)
    h.check()
    assert [h.bm.ref_count(b) for b in matched] == [1, 1, 0]  # pins only
    assert h.bm.match_prefix(FAMILIES[0][:3 * BS + 1]) == matched[:2]
    h.resume(1)
    assert h.bm.block_table(1)[:2] == matched[:2]
    h.free(1)
    h.check()
    assert h.bm.free_blocks == h.bm.num_blocks


def test_cow_on_shared_tail_isolates_writer():
    """fork + append: the writer moves onto a private copy, the reader
    keeps the original block, and the drained COW op names the pair."""
    h = _Harness()
    h.alloc(0, 1, BS + 2)                  # partial tail block
    tail = h.bm.block_table(0)[-1]
    fork_sid = 1
    h.fork(0, fork_sid)                    # fork(src, new)
    h.drained_cow.extend(h.bm.take_cow_ops())
    assert h.bm.has(fork_sid)
    assert h.bm.block_table(fork_sid)[-1] != tail     # eager tail COW
    assert h.bm.block_table(fork_sid)[:-1] == h.bm.block_table(0)[:-1]
    assert (tail, h.bm.block_table(fork_sid)[-1]) in h.drained_cow
    # both may now append freely without further COW
    before = h.bm.free_blocks
    h.append(0)
    h.append(fork_sid)
    assert h.bm.free_blocks == before      # still inside their own blocks
    h.check()


# ---------------------------------------------------------------------------
# hypothesis-driven walk (optional dep; CI installs it)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                         # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=120, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(OPS), st.integers(0, 7),
                              st.integers(1, CAP), st.integers(0, 2)),
                    max_size=100))
    def test_hypothesis_random_walk(ops):
        h = _run_walk(ops)
        for sid in list(h.bm._seqs):
            h.free(sid)
        for sid in list(h.snapshots):
            h.release(sid)
        h.check()
        assert h.bm.free_blocks == h.bm.num_blocks
else:                                       # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_random_walk():
        pass
