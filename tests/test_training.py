"""Training substrate: optimizer math, learning signal, microbatch
equivalence, checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import build_model
from repro.training import (AdamW, SyntheticLMDataset, cosine_schedule,
                            make_train_step, restore_checkpoint,
                            save_checkpoint)


def test_adamw_minimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0, grad_clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.0)
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


def test_loss_decreases_on_structured_data():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(learning_rate=3e-3)
    step = jax.jit(make_train_step(model, opt))
    state = opt.init(params)
    ds = iter(SyntheticLMDataset(cfg.vocab_size, 32, 8, seed=0))
    losses = []
    for _ in range(60):
        params, state, m = step(params, state, dict(next(ds)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, \
        (np.mean(losses[:10]), np.mean(losses[-10:]))


def test_microbatch_accumulation_matches_full_batch():
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(learning_rate=1e-3, grad_clip_norm=None)
    tokens = jax.random.randint(jax.random.key(1), (8, 17), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    p1, _, m1 = jax.jit(make_train_step(model, opt))(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(
        params, opt.init(params), batch)
    # mean-of-microbatch-losses == full-batch loss (uniform shapes)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    d = jax.tree.reduce(lambda a, b: max(a, b),
                        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4))
    assert d < 1e-5


def test_checkpoint_roundtrip():
    cfg = ARCHITECTURES["mamba2-130m"].reduced(num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, step=42, metadata={"arch": cfg.name})
        restored, step = restore_checkpoint(d, jax.eval_shape(lambda: params))
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_clip_bounds_update():
    opt = AdamW(learning_rate=1.0, grad_clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    # clipped grad => bounded first-moment estimate => bounded update
    assert float(jnp.abs(updates["w"]).max()) <= 1.1
