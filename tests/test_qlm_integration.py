"""End-to-end: QLM controller + LSO agents over REAL JAX engines (reduced
models) — the full paper stack executing actual forward passes."""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, EngineConfig


@pytest.fixture(scope="module")
def stack():
    key = jax.random.key(0)
    registry = {}
    for name in ("granite-3-2b", "h2o-danube-1.8b"):
        cfg = ARCHITECTURES[name].reduced(num_layers=2, d_model=128)
        model = build_model(cfg)
        registry[name] = (model, model.init(key))
    return registry


def _hw():
    return HardwareProfile(prefill_time=0.05, decode_per_token=0.02,
                           inefficiency=1.2, token_capacity=512,
                           swap_time=0.2, model_max_tokens=32)


def test_full_stack_multi_model_serving(stack):
    registry = stack
    names = list(registry)
    ecfg = EngineConfig(max_slots=4, max_seq_len=64)
    m0, p0 = registry[names[0]]
    eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=names[0])
    vq = VirtualQueue(0)
    agent = QLMAgent(eng, vq, registry)
    info = InstanceInfo(0, {n: _hw() for n in names}, eng.model_name, vq)
    controller = QLMController([info], QLMConfig(avg_batch_size=4,
                                                 reschedule_cooldown=0.0))

    rng = np.random.default_rng(0)
    now = time.monotonic()
    reqs = []
    for i in range(10):
        r = make_request(rng.integers(0, 100, size=6).tolist(),
                         names[i % 2], "batch1", arrival_time=now,
                         max_new_tokens=4)
        reqs.append(r)
        controller.submit(r, now)

    for _ in range(300):
        info.current_model = eng.model_name
        agent.run_iteration()
        if all(r.finished() for r in reqs):
            break
    assert all(r.finished() for r in reqs)
    assert eng.stats.model_swaps >= 1          # served both models
    # group-level swapping: far fewer swaps than per-request alternation
    assert eng.stats.model_swaps <= 4
    assert controller.slo_attainment() == 1.0  # relaxed SLOs all met


def test_agent_eviction_on_head_change(stack):
    registry = stack
    names = list(registry)
    ecfg = EngineConfig(max_slots=2, max_seq_len=64, kv_blocks=8, block_size=8)
    m0, p0 = registry[names[0]]
    eng = ContinuousBatchingEngine(m0, p0, ecfg, model_name=names[0])
    vq = VirtualQueue(0)
    agent = QLMAgent(eng, vq, registry)

    from repro.core.request_group import RequestGroup
    # batch group hogs the device
    g_batch = RequestGroup(model=names[0], slo=3600.0)
    for _ in range(2):
        g_batch.add(make_request(list(range(20)), names[0], "batch2",
                                 max_new_tokens=30))
    vq.set_order([g_batch])
    for _ in range(3):
        agent.run_iteration()
    assert eng.num_active() == 2

    # interactive group jumps to the head (global-scheduler decision)
    g_int = RequestGroup(model=names[0], slo=20.0)
    g_int.add(make_request(list(range(30)), names[0], "interactive",
                           max_new_tokens=2))
    vq.set_order([g_int, g_batch])
    for _ in range(10):
        agent.run_iteration()
        if eng.stats.evictions > 0:
            break
    assert eng.stats.evictions >= 1            # HOL un-blocked by eviction
    for _ in range(40):
        agent.run_iteration()
        if g_int.requests[0].finished():
            break
    assert g_int.requests[0].finished()
